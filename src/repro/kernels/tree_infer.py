"""Pallas TPU kernel: fused approximate-DT/forest inference (the paper's hot loop).

The GA evaluates `population x test_set` predictions every generation. This
kernel computes one (chromosome, batch-block) cell of that product with the
*parallel bespoke circuit* dataflow (DESIGN.md §2), fully gather-free so every
step lands on the MXU / VPU:

    x_sel   = X8 @ SEL            feature gather as one-hot matmul  (MXU)
    x_p     = floor(x_sel * 2^-(8-p))   per-comparator precision    (VPU)
    d       = x_p > t'                   comparator array           (VPU)
    score   = d @ PATH^T                 path matmul                (MXU)
    sat     = (score == target)          leaf decode                (VPU)
    votes   = sat @ CLS1H                vote matmul                (MXU)

For a single tree exactly one leaf satisfies its path, so `votes` is the
one-hot of the predicted class. For a *forest* the same program evaluates all
trees at once (DESIGN.md §7): the comparator axis is the concatenation of all
trees' comparators, PATH is block-diagonal (leaf rows only see their own
tree's comparators), and one leaf per tree fires — `votes` then accumulates
one vote per tree per class, i.e. the vote matmul IS the majority-vote adder
tree of the bespoke RF circuit. argmax over classes = voted prediction.

Block layout (VMEM): the tree tensors (SEL: F x N, PATH: N x L, CLS1H: L x C)
stay resident per grid cell; the batch is tiled by `block_b` rows and the leaf
axis may additionally be tiled by `block_l` (forests concatenate many trees'
leaves, so L can outgrow a single VMEM-resident block). Grid =
(population, batch_blocks, leaf_blocks): each chromosome's per-comparator
(shift_scale, threshold) vector is a [1, N] VMEM tile indexed by the
population coordinate; the leaf axis is the innermost (sequential) grid
dimension so partial vote matmuls accumulate into the same revisited output
block.

All integer quantities are exact in f32 (values < 2^24) and vote accumulation
adds small exact integers, so MXU execution is bit-exact vs the integer
reference in `repro.kernels.ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, sel_ref, scale_ref, thr_ref, path_ref, target_ref,
            cls1h_ref, out_ref):
    # x_ref:      (block_b, F)    f32   master 8-bit codes
    # sel_ref:    (F, N)          f32   one-hot feature selector
    # scale_ref:  (1, N)          f32   2^-(8-p) per comparator (this chromosome)
    # thr_ref:    (1, N)          f32   substituted integer threshold t'
    # path_ref:   (N, block_l)    f32   path matrix transpose, entries {-1,0,1}
    # target_ref: (1, block_l)    f32   path_len - n_neg
    # cls1h_ref:  (block_l, C)    f32   leaf -> class one-hot
    # out_ref:    (1, block_b, C) f32   per-class vote counts (accumulated
    #                                   over the leaf-block grid dimension)
    x = x_ref[...]
    x_sel = jax.lax.dot(x, sel_ref[...], precision=jax.lax.Precision.HIGHEST)
    x_p = jnp.floor(x_sel * scale_ref[...])
    d = (x_p > thr_ref[...]).astype(jnp.float32)
    score = jax.lax.dot(d, path_ref[...], precision=jax.lax.Precision.HIGHEST)
    sat = (score == target_ref[...]).astype(jnp.float32)
    votes = jax.lax.dot(sat, cls1h_ref[...],
                        precision=jax.lax.Precision.HIGHEST)

    l_idx = pl.program_id(2)

    @pl.when(l_idx == 0)
    def _init():
        out_ref[0, :, :] = votes

    @pl.when(l_idx != 0)
    def _accum():
        out_ref[0, :, :] += votes


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_l", "interpret")
)
def tree_infer_scores(
    x8f,      # (B, F)  f32 master codes (padded: B % block_b == 0, F % 128 == 0)
    sel,      # (F, N)  f32
    scale,    # (P, N)  f32 per-chromosome shift scales
    thr,      # (P, N)  f32 per-chromosome substituted thresholds
    path_t,   # (N, L)  f32
    target,   # (1, L)  f32
    cls1h,    # (L, C)  f32
    *,
    block_b: int = 256,
    block_l: int | None = None,
    interpret: bool = False,
):
    """Returns per-class vote counts (P, B, C); argmax over C = prediction.

    ``block_l`` tiles the leaf axis (must divide L); ``None`` keeps the whole
    (padded) leaf axis resident — the single-tree fast path.
    """
    n_pop = scale.shape[0]
    b, f = x8f.shape
    n = sel.shape[1]
    l, c = cls1h.shape
    if block_l is None:
        block_l = l
    if l % block_l != 0:
        raise ValueError(f"block_l={block_l} must divide padded L={l}")
    grid = (n_pop, b // block_b, l // block_l)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda p, i, j: (i, 0)),
            pl.BlockSpec((f, n), lambda p, i, j: (0, 0)),
            pl.BlockSpec((1, n), lambda p, i, j: (p, 0)),
            pl.BlockSpec((1, n), lambda p, i, j: (p, 0)),
            pl.BlockSpec((n, block_l), lambda p, i, j: (0, j)),
            pl.BlockSpec((1, block_l), lambda p, i, j: (0, j)),
            pl.BlockSpec((block_l, c), lambda p, i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, c), lambda p, i, j: (p, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pop, b, c), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x8f, sel, scale, thr, path_t, target, cls1h)
