"""Pallas TPU kernel: fused approximate-DT inference (the paper's hot loop).

The GA evaluates `population x test_set` predictions every generation. This
kernel computes one (chromosome, batch-block) cell of that product with the
*parallel bespoke circuit* dataflow (DESIGN.md §2), fully gather-free so every
step lands on the MXU / VPU:

    x_sel   = X8 @ SEL            feature gather as one-hot matmul  (MXU)
    x_p     = floor(x_sel * 2^-(8-p))   per-comparator precision    (VPU)
    d       = x_p > t'                   comparator array           (VPU)
    score   = d @ PATH^T                 path matmul                (MXU)
    sat     = (score == target)          leaf decode                (VPU)
    cls     = argmax(sat @ CLS1H)        class one-hot reduce       (MXU)

Block layout (VMEM): the tree tensors (SEL: F x N, PATH: L x N, CLS1H: L x C)
are small (N, L <= 1024 after padding) and stay resident; the batch is tiled
by `block_b` rows. Grid = (population, batch_blocks): each chromosome's
per-comparator (shift_scale, threshold) vector is a [1, N] VMEM tile indexed
by the population coordinate.

All integer quantities are exact in f32 (values < 2^24), so MXU execution is
bit-exact vs the integer reference in `repro.kernels.ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, sel_ref, scale_ref, thr_ref, path_ref, target_ref,
            cls1h_ref, out_ref):
    # x_ref:      (block_b, F)   f32   master 8-bit codes
    # sel_ref:    (F, N)         f32   one-hot feature selector
    # scale_ref:  (1, N)         f32   2^-(8-p) per comparator (this chromosome)
    # thr_ref:    (1, N)         f32   substituted integer threshold t'
    # path_ref:   (N, L)         f32   path matrix transpose, entries {-1,0,1}
    # target_ref: (1, L)         f32   path_len - n_neg
    # cls1h_ref:  (L, C)         f32   leaf -> class one-hot
    # out_ref:    (block_b, C)   f32   per-class satisfied-leaf counts
    x = x_ref[...]
    x_sel = jax.lax.dot(x, sel_ref[...], precision=jax.lax.Precision.HIGHEST)
    x_p = jnp.floor(x_sel * scale_ref[...])
    d = (x_p > thr_ref[...]).astype(jnp.float32)
    score = jax.lax.dot(d, path_ref[...], precision=jax.lax.Precision.HIGHEST)
    sat = (score == target_ref[...]).astype(jnp.float32)
    out_ref[0, :, :] = jax.lax.dot(sat, cls1h_ref[...],
                                   precision=jax.lax.Precision.HIGHEST)


@functools.partial(
    jax.jit, static_argnames=("block_b", "interpret")
)
def tree_infer_scores(
    x8f,      # (B, F)  f32 master codes (padded: B % block_b == 0, F % 128 == 0)
    sel,      # (F, N)  f32
    scale,    # (P, N)  f32 per-chromosome shift scales
    thr,      # (P, N)  f32 per-chromosome substituted thresholds
    path_t,   # (N, L)  f32
    target,   # (1, L)  f32
    cls1h,    # (L, C)  f32
    *,
    block_b: int = 256,
    interpret: bool = False,
):
    """Returns per-class scores (P, B, C); argmax over C = predicted class."""
    n_pop = scale.shape[0]
    b, f = x8f.shape
    n = sel.shape[1]
    l, c = cls1h.shape
    grid = (n_pop, b // block_b)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda p, i: (i, 0)),
            pl.BlockSpec((f, n), lambda p, i: (0, 0)),
            pl.BlockSpec((1, n), lambda p, i: (p, 0)),
            pl.BlockSpec((1, n), lambda p, i: (p, 0)),
            pl.BlockSpec((n, l), lambda p, i: (0, 0)),
            pl.BlockSpec((1, l), lambda p, i: (0, 0)),
            pl.BlockSpec((l, c), lambda p, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, c), lambda p, i: (p, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pop, b, c), jnp.float32),
        interpret=interpret,
    )(x8f, sel, scale, thr, path_t, target, cls1h)
