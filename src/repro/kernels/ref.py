"""Pure-jnp oracles for every Pallas kernel (bit-exact references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_infer_scores(x8f, sel, scale, thr, path_t, target, cls1h):
    """Oracle for kernels.tree_infer.tree_infer_scores. Same padded operands.

    x8f (B, F) f32; sel (F, N); scale/thr (P, N); path_t (N, L);
    target (1, L); cls1h (L, C). Returns (P, B, C) f32.
    """
    x_sel = x8f @ sel                                     # (B, N)
    x_p = jnp.floor(x_sel[None] * scale[:, None, :])      # (P, B, N)
    d = (x_p > thr[:, None, :]).astype(jnp.float32)
    score = jnp.einsum("pbn,nl->pbl", d, path_t)
    sat = (score == target[None]).astype(jnp.float32)
    return jnp.einsum("pbl,lc->pbc", sat, cls1h)


def fitness_correct_counts(x_sel, scale, thr, path_t, target, cls1h, y,
                           vote_cap=None):
    """Oracle for kernels.fitness.fitness_errors. Same padded operands.

    x_sel (B, N) f32 hoisted gathered codes; scale/thr (P, N); path_t (N, L);
    target (1, L); cls1h (L, C); y (1, B) f32 labels (-1 on padded rows);
    vote_cap (P,) f32 optional vote saturation (DESIGN.md §16; +inf rows are
    an exact no-op, matching the kernel's lane-replicated cap operand).
    Returns (P,) f32 correct-sample counts (the kernel's lane-replicated
    accumulator collapsed to one lane).
    """
    x_p = jnp.floor(x_sel[None] * scale[:, None, :])      # (P, B, N)
    d = (x_p > thr[:, None, :]).astype(jnp.float32)
    score = jnp.einsum("pbn,nl->pbl", d, path_t)
    sat = (score == target[None]).astype(jnp.float32)
    votes = jnp.einsum("pbl,lc->pbc", sat, cls1h)
    if vote_cap is not None:
        votes = jnp.minimum(votes, vote_cap[:, None, None])
    pred = jnp.argmax(votes, axis=-1).astype(jnp.float32)  # (P, B)
    return jnp.sum((pred == y).astype(jnp.float32), axis=-1)


def domination_matrix(objs, against=None):
    """Oracle for kernels.domination.domination_block / domination_matrix.

    objs (Pi, M) rows vs ``against`` (Pj, M) columns (default: objs — the
    square case) -> (Pi, Pj) f32."""
    a = objs[:, None, :]
    b = (objs if against is None else against)[None, :, :]
    dom = jnp.all(a <= b, axis=-1) & jnp.any(a < b, axis=-1)
    return dom.astype(jnp.float32)


def qmatmul(x, w_q, scale):
    """Oracle for kernels.qmatmul.qmatmul."""
    return (x.astype(jnp.float32) @ w_q.astype(jnp.float32)) * scale


def flash_attention(q, k, v, group=1, softcap=0.0):
    """Oracle for kernels.flash_attn.flash_attention: plain causal softmax
    attention with GQA via head grouping. q (H,Sq,hd); k/v (Hkv,Skv,hd)."""
    h, sq, hd = q.shape
    k_rep = jnp.repeat(k, group, axis=0)
    v_rep = jnp.repeat(v, group, axis=0)
    sc = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                    k_rep.astype(jnp.float32)) * (hd ** -0.5)
    if softcap > 0:
        sc = jnp.tanh(sc / softcap) * softcap
    skv = k.shape[1]
    mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
    sc = jnp.where(mask[None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", p, v_rep.astype(jnp.float32))
    return out.astype(q.dtype)
