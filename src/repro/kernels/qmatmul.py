"""Pallas TPU kernel: mixed-precision dequantize-matmul.

This carries the paper's dual approximation to the LM architectures
(DESIGN.md §5): weights are stored at low precision (2..8-bit codes held in
int8) after hardware-friendly value snapping, with one scale per output
channel — the LM analogue of the per-comparator (precision, substituted
threshold) genes. The kernel fuses dequantization into a blocked matmul so
low-bit weights never round-trip through HBM at f32 width.

Classic 3-D blocked matmul: grid (m_blocks, n_blocks, k_blocks), K innermost
("arbitrary") with a VMEM f32 accumulator; MXU-aligned 128x tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, scale_ref, out_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, bk)
    w = w_ref[...].astype(jnp.float32)            # (bk, bn) int8 codes -> f32
    acc_ref[...] += jax.lax.dot(x, w, precision=jax.lax.Precision.HIGHEST)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        out_ref[...] = acc_ref[...] * scale_ref[...]   # (1, bn) broadcast


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def qmatmul(
    x,        # (M, K) f32/bf16 activations
    w_q,      # (K, N) int8 quantized codes (2..8-bit range, snapped)
    scale,    # (1, N) f32 per-output-channel dequant scale
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
):
    m, k = x.shape
    _, n = w_q.shape
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    kernel = functools.partial(_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w_q, scale)
