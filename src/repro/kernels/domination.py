"""Pallas TPU kernel: NSGA-II pairwise domination matrix (blocked O(P^2)).

Fast non-dominated sort needs dom[i, j] = all(o_i <= o_j) & any(o_i < o_j)
for the combined 2P pool every generation. For production population sizes
(P up to tens of thousands sharded per device) the P x P boolean matrix is
the dominant VPU cost; this kernel tiles it (block_i x block_j) in VMEM with
the (small, static) objective count unrolled.

Two entry points share the kernel body:

  `domination_matrix`  — the square (P, P) relation of one pool against
                         itself (the monolithic sort path);
  `domination_block`   — a rectangular (Pi, Pj) slab: rows from one operand
                         set, columns from another. The mesh-sharded
                         hierarchical sort (DESIGN.md §13) gives each shard
                         its local population slab as rows and the
                         all-gathered pool as columns, so per-shard pairwise
                         work drops from O(P^2) to O(P^2 / n_shards) while
                         the row-partitioned matrix stays bit-identical to
                         the monolithic one.

Output is f32 {0., 1.} — downstream reductions (domination counts) are sums,
and f32 keeps the 8x128 VPU lanes dense.

Wired into the sort path: on TPU, `core.nsga2.non_dominated_sort` routes
through this kernel (via `kernels.ops.domination_matrix_bool`, which pads
internally) whenever the *row* operand — the local population slab under
sharding — reaches `nsga2.DOMINATION_KERNEL_MIN_POP`; below that — and
everywhere off-TPU, where this kernel only runs in the (slow, bit-exact)
Pallas interpreter — the pure-jnp broadcast, the kernel's oracle, is the
right call (DESIGN.md §9, §13).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(obj_i_ref, obj_j_ref, out_ref, *, n_obj: int):
    # obj_i_ref: (block_i, M) f32; obj_j_ref: (block_j, M) f32
    le = None
    lt = None
    for k in range(n_obj):  # static unroll over objectives
        a = obj_i_ref[:, k][:, None]     # (block_i, 1)
        b = obj_j_ref[:, k][None, :]     # (1, block_j)
        le_k = a <= b
        lt_k = a < b
        le = le_k if le is None else (le & le_k)
        lt = lt_k if lt is None else (lt | lt_k)
    out_ref[...] = (le & lt).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def domination_block(
    objs_i,  # (Pi, M) f32, Pi % block_i == 0 after padding
    objs_j,  # (Pj, M) f32, Pj % block_j == 0 after padding
    *,
    block_i: int = 256,
    block_j: int = 256,
    interpret: bool = False,
):
    """dom (Pi, Pj) f32: dom[i, j] = 1 iff objs_i[i] dominates objs_j[j].

    The rectangular row-slab form of `domination_matrix`: the grid tiles the
    two operand sets independently, so a population shard can compute just
    its rows of the global relation (DESIGN.md §13)."""
    pi, m = objs_i.shape
    pj, mj = objs_j.shape
    if m != mj:
        raise ValueError(f"objective counts differ: {m} vs {mj}")
    grid = (pi // block_i, pj // block_j)
    kernel = functools.partial(_kernel, n_obj=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, m), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, block_j), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pi, pj), jnp.float32),
        interpret=interpret,
    )(objs_i, objs_j)


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def domination_matrix(
    objs,  # (P, M) f32, P % block == 0 after padding
    *,
    block_i: int = 256,
    block_j: int = 256,
    interpret: bool = False,
):
    """dom (P, P) f32: dom[i, j] = 1 iff i dominates j (minimization)."""
    return domination_block(objs, objs, block_i=block_i, block_j=block_j,
                            interpret=interpret)
