"""Pallas TPU kernel: causal flash attention with GQA (the §Perf next-lever
for the prefill cells).

The jnp chunked attention in models/attention.py materializes (S, chunk)
score blocks in HBM on the XLA-CPU dry-run; this kernel keeps the running
(m, l, acc) softmax state and the score block in VMEM — the memory-term
upper bound in EXPERIMENTS.md §Roofline collapses to the q/k/v/o streams.

Layout: q (H, Sq, hd) with H = B * n_q_heads (flattened); k/v (Hkv, Skv, hd)
with GQA group factor G = H/Hkv resolved by the k/v index_map (q head h
reads kv head h // G). Grid = (H, q_blocks, kv_blocks), kv innermost
("arbitrary"); causal masking by absolute position; the out block is
finalized on the last kv step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_q: int, block_k: int, n_kv: int, scale: float,
            softcap: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, :].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, :, :].astype(jnp.float32)            # (bk, hd)
    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             precision=jax.lax.Precision.HIGHEST) * scale
    if softcap > 0:
        sc = jnp.tanh(sc / softcap) * softcap
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    sc = jnp.where(q_pos >= k_pos, sc, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(sc - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    m_ref[...] = m_new
    pv = jax.lax.dot(p.astype(v_ref.dtype), v_ref[0, :, :],
                     precision=jax.lax.Precision.HIGHEST)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv.astype(jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group", "block_q", "block_k", "softcap", "interpret"))
def flash_attention(
    q,        # (H, Sq, hd)
    k,        # (Hkv, Skv, hd)
    v,        # (Hkv, Skv, hd)
    *,
    group: int = 1,          # H / Hkv
    block_q: int = 256,
    block_k: int = 256,
    softcap: float = 0.0,
    interpret: bool = False,
):
    h, sq, hd = q.shape
    _, skv, _ = k.shape
    n_q = sq // block_q
    n_kv = skv // block_k
    scale = hd ** -0.5
    grid = (h, n_q, n_kv)
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, n_kv=n_kv, scale=scale,
        softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda hh, qi, ki: (hh, qi, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda hh, qi, ki, g=group: (hh // g, ki, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda hh, qi, ki, g=group: (hh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda hh, qi, ki: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
