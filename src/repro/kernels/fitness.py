"""Pallas TPU kernel: population-tiled fused fitness (DESIGN.md §12).

`tree_infer_scores` (kernels.tree_infer) evaluates the GA's
`population x test_set` product but materializes a full (P, B, C) vote
tensor to HBM, re-runs the chromosome-invariant `X8 @ SEL` feature-gather
matmul in every grid cell, and streams each chromosome's operands as (1, N)
tiles that leave 7 of 8 VPU sublanes idle. This kernel is the fused-fitness
replacement: the argmax + label compare + batch reduction happen *inside*
the kernel, so the only HBM write is the per-chromosome correct-count
accumulator — O(P) instead of O(P·B·C) — and the feature gather is hoisted
out entirely (the caller passes the precomputed `x_sel (B, N)` once per
problem, see `search.problem`/`kernels.ops.prepare_fitness_operands`).

Per grid cell, a `(block_p, N)` slab of chromosomes meets a `(block_b, N)`
batch tile of hoisted codes:

    x_p    = floor(x_sel * 2^-(8-p))      broadcast over block_p      (VPU)
    d      = x_p > t'                     (block_p, block_b, N)       (VPU)
    score  = d @ PATH^T                   batched path matmul         (MXU)
    sat    = (score == target)            leaf decode                 (VPU)
    votes  = sat @ CLS1H                  batched vote matmul         (MXU)
    [accumulate votes over leaf blocks in VMEM scratch]
    pred   = first-max argmax over C      iota + masked min           (VPU)
    out   += sum_b (pred == y)            per-chromosome correct count

Grid = (pop_blocks, batch_blocks, leaf_blocks); the leaf axis is innermost
so partial vote matmuls accumulate into the VMEM scratch, and the batch
axis is sequential so the (block_p, LANES) output block — lane-replicated
so the accumulator stays a native f32 tile — is revisited, not re-written.

All integer quantities are exact in f32 (< 2^24) and every reduction adds
small exact integers, so the errors computed here match
`argmax(tree_infer_scores) != y` bit-for-bit; `tree_infer_scores` stays the
materializing oracle (tests assert equality, see tests/test_fitness.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# The correct-count accumulator is replicated across one full lane tile so
# the output block is a native (block_p, 128) f32 tile; callers read lane 0.
LANES = 128


def _kernel(xsel_ref, scale_ref, thr_ref, path_ref, target_ref, cls1h_ref,
            y_ref, vcap_ref, out_ref, votes_ref):
    # xsel_ref:   (block_b, N)           f32  hoisted gathered master codes
    # scale_ref:  (block_p, N)           f32  2^-(8-p) per comparator
    # thr_ref:    (block_p, N)           f32  substituted integer threshold t'
    # path_ref:   (N, block_l)           f32  path matrix transpose
    # target_ref: (1, block_l)           f32  path_len - n_neg
    # cls1h_ref:  (block_l, C)           f32  leaf -> class one-hot
    # y_ref:      (1, block_b)           f32  labels (-1 on padded rows)
    # vcap_ref:   (block_p, LANES)       f32  lane-replicated vote caps
    #                                         (1.0 approx adder, +inf exact)
    # out_ref:    (block_p, LANES)       f32  lane-replicated correct counts
    # votes_ref:  (block_p, block_b, C)  f32  VMEM vote accumulator
    x = xsel_ref[...]
    x_p = jnp.floor(x[None, :, :] * scale_ref[...][:, None, :])
    d = (x_p > thr_ref[...][:, None, :]).astype(jnp.float32)
    score = jax.lax.dot_general(
        d, path_ref[...], dimension_numbers=(((2,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST)
    sat = (score == target_ref[...][None, :, :]).astype(jnp.float32)
    votes = jax.lax.dot_general(
        sat, cls1h_ref[...], dimension_numbers=(((2,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST)

    b_idx = pl.program_id(1)
    l_idx = pl.program_id(2)

    @pl.when(l_idx == 0)
    def _init_votes():
        votes_ref[...] = votes

    @pl.when(l_idx != 0)
    def _accum_votes():
        votes_ref[...] += votes

    @pl.when((b_idx == 0) & (l_idx == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    # last leaf block: votes are complete for this (pop, batch) tile —
    # reduce to correct counts on-chip instead of spilling (P, B, C) to HBM
    @pl.when(l_idx == pl.num_programs(2) - 1)
    def _reduce():
        v = votes_ref[...]                                 # (bp, bb, C)
        # saturating (approximate) vote adder, DESIGN.md §16: clip the
        # accumulated counts to the per-chromosome cap (+inf = exact no-op)
        v = jnp.minimum(v, vcap_ref[...][:, :1][:, :, None])
        n_cls = v.shape[-1]
        vmax = jnp.max(v, axis=-1, keepdims=True)
        cls = jax.lax.broadcasted_iota(jnp.float32, v.shape, 2)
        # first-max argmax as iota + masked min (jnp.argmax tie semantics)
        pred = jnp.min(jnp.where(v == vmax, cls, jnp.float32(n_cls)), axis=-1)
        correct = (pred == y_ref[...]).astype(jnp.float32)  # (bp, bb)
        out_ref[...] += jnp.sum(correct, axis=1)[:, None]


@functools.partial(
    jax.jit, static_argnames=("block_p", "block_b", "block_l", "interpret")
)
def fitness_errors(
    x_sel,    # (B, N)  f32 hoisted gathered codes (padded: B % block_b == 0,
              #             N % 128 == 0)
    scale,    # (P, N)  f32 per-chromosome shift scales (P % block_p == 0)
    thr,      # (P, N)  f32 per-chromosome substituted thresholds
    path_t,   # (N, L)  f32
    target,   # (1, L)  f32
    cls1h,    # (L, C)  f32
    y,        # (1, B)  f32 labels, -1 on padded batch rows
    vote_cap,  # (P, LANES) f32 lane-replicated vote caps (+inf = exact)
    *,
    block_p: int = 8,
    block_b: int = 256,
    block_l: int | None = None,
    interpret: bool = False,
):
    """Lane-replicated per-chromosome correct counts, shape (P, LANES).

    ``out[p, 0]`` is the number of test samples chromosome ``p`` classifies
    correctly (padded rows carry label -1 and never match); errors are
    ``n_valid - out[:, 0]``. ``block_p`` tiles the population axis,
    ``block_l`` the (concatenated) leaf axis — both must divide the padded
    extents.
    """
    n_pop = scale.shape[0]
    b, n = x_sel.shape
    l, c = cls1h.shape
    if block_l is None:
        block_l = l
    if n_pop % block_p != 0:
        raise ValueError(f"block_p={block_p} must divide padded P={n_pop}")
    if b % block_b != 0:
        raise ValueError(f"block_b={block_b} must divide padded B={b}")
    if l % block_l != 0:
        raise ValueError(f"block_l={block_l} must divide padded L={l}")
    grid = (n_pop // block_p, b // block_b, l // block_l)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda p, i, j: (i, 0)),
            pl.BlockSpec((block_p, n), lambda p, i, j: (p, 0)),
            pl.BlockSpec((block_p, n), lambda p, i, j: (p, 0)),
            pl.BlockSpec((n, block_l), lambda p, i, j: (0, j)),
            pl.BlockSpec((1, block_l), lambda p, i, j: (0, j)),
            pl.BlockSpec((block_l, c), lambda p, i, j: (j, 0)),
            pl.BlockSpec((1, block_b), lambda p, i, j: (0, i)),
            pl.BlockSpec((block_p, LANES), lambda p, i, j: (p, 0)),
        ],
        out_specs=pl.BlockSpec((block_p, LANES), lambda p, i, j: (p, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pop, LANES), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_p, block_b, c), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x_sel, scale, thr, path_t, target, cls1h, y, vote_cap)
