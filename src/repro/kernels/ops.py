"""Jitted public wrappers around the Pallas kernels.

Handle padding to MXU-aligned tiles, operand preparation (one-hot selector /
path matrices, per-chromosome threshold decode) and CPU fallback: on a CPU
backend the kernels execute with ``interpret=True`` (the Pallas interpreter
runs the kernel body in Python), on TPU they compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.tree import ParallelTree, concatenate_ptrees
from repro.kernels import domination as _dom
from repro.kernels import fitness as _fit
from repro.kernels import qmatmul as _qmm
from repro.kernels import tree_infer as _ti


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, axis, value=0.0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# tree_infer
# ---------------------------------------------------------------------------

def prepare_operands(feature, path, path_len, n_neg, leaf_class,
                     n_classes: int, n_features: int):
    """Padded kernel operands from (concatenated) comparator/leaf arrays.

    `path` (L, N) may be a single tree's path matrix or the block-diagonal
    super-tree of a forest (e.g. `SearchProblem.path`) — the kernel dataflow
    is identical either way (DESIGN.md §7).

    Padding is correctness-preserving:
      - SEL extra columns are all-zero -> x_sel = 0, thr pad = 2^8 so the
        padded comparator always outputs 0;
      - PATH pad rows/cols are zero; target pad = -1 is unsatisfiable, so
        padded leaves never fire; padded classes never win argmax.
    """
    feature = np.asarray(feature)
    path = np.asarray(path)
    path_len = np.asarray(path_len)
    n_neg = np.asarray(n_neg)
    leaf_class = np.asarray(leaf_class)
    l, n = path.shape
    sel = np.zeros((n_features, n), np.float32)
    sel[feature, np.arange(n)] = 1.0
    path_t = path.T.astype(np.float32)                          # (N, L)
    target = (path_len - n_neg).astype(np.float32)[None]        # (1, L)
    cls1h = np.zeros((l, n_classes), np.float32)
    cls1h[np.arange(l), leaf_class] = 1.0

    sel = _pad_to(_pad_to(jnp.asarray(sel), 128, 0), 128, 1)
    path_t = _pad_to(_pad_to(jnp.asarray(path_t), 128, 0), 128, 1)
    target = _pad_to(jnp.asarray(target), 128, 1, value=-1.0)
    cls1h = _pad_to(_pad_to(jnp.asarray(cls1h), 128, 0), 128, 1)
    return sel, path_t, target, cls1h


def prepare_forest_operands(ptrees, n_features: int):
    """Static operands for fused multi-tree inference (DESIGN.md §7).

    The forest is laid out as one block-diagonal "super-tree": the comparator
    axis concatenates every tree's comparators, the leaf axis every tree's
    leaves, and PATH^T is block-diagonal so each leaf row only sees its own
    tree's comparators. Exactly one leaf per tree satisfies its path, so the
    vote matmul (sat @ CLS1H) accumulates one vote per tree per class — the
    kernel's argmax IS the majority vote, with no per-tree Python loop.

    A single ``ParallelTree`` is the K=1 special case (`prepare_tree_operands`).
    """
    arrays = concatenate_ptrees(ptrees)
    return prepare_operands(
        arrays["feature"], arrays["path"], arrays["path_len"],
        arrays["n_neg"], arrays["leaf_class"],
        max(pt.n_classes for pt in ptrees), n_features,
    )


def prepare_tree_operands(pt: ParallelTree, n_features: int):
    """Single-tree operands: the K=1 case of `prepare_forest_operands`."""
    return prepare_forest_operands([pt], n_features)


def decode_population_full(threshold, genes):
    """ONE gene decode shared by the accuracy and area terms (DESIGN.md §12).

    threshold (N,) float; genes (P, 3N+1) in the cross-layer layout
    (DESIGN.md §16). Returns (scale, t_sub, bits, vote_cap): scale/t_sub/
    bits are (P, N) EFFECTIVE comparator operands with LSB truncation
    already folded in — width p - k, threshold t' >> k, shift scale
    2^-(8-p+k) — because a k-truncated comparator IS the exact comparator
    at that width, so the kernel compare needs no new op. `t_sub` (int32)
    indexes the area LUT directly (cast to f32 for the kernel); `vote_cap`
    (P,) f32 is the vote saturation (1.0 approx adder, +inf exact — an
    exact f32 no-op). Historically the kernel fitness decoded twice — once
    for scale/thr, once more for the area LUT index — doubling the
    per-chromosome decode work.
    """
    bits, margin, trunc, vote = quant.decode_tree_genes(genes)  # (P, N) each
    t_int = quant.threshold_to_int(threshold[None, :], bits)
    t_sub = quant.substitute(t_int, margin, bits)
    bits_eff = bits - trunc
    t_eff = jnp.right_shift(t_sub, trunc)
    scale = jnp.exp2(-(8 - bits_eff).astype(jnp.float32))
    vote_cap = jnp.where(vote > 0, jnp.float32(1.0), jnp.float32(jnp.inf))
    return scale, t_eff, bits_eff, vote_cap


def decode_population(threshold, genes):
    """Per-chromosome kernel operands from real-coded genes.

    threshold (N,) float; genes (P, 3N+1). Returns scale (P, N), thr (P, N)
    f32 (effective, truncation folded in) and vote_cap (P,) f32.
    """
    scale, t_sub, _, vote_cap = decode_population_full(threshold, genes)
    return scale, t_sub.astype(jnp.float32), vote_cap


@functools.partial(jax.jit, static_argnames=("block_b", "block_l", "interpret"))
def tree_infer_predict(x8, pt_operands, scale, thr, vote_cap=None, *,
                       block_b=256, block_l=None, interpret=None):
    """(P, B) predicted classes for a population of approximate trees/forests.

    x8 (B, F) int; pt_operands from prepare_tree_operands /
    prepare_forest_operands (already padded); scale/thr (P, N_padded-able);
    vote_cap (P,) f32 optional vote saturation (DESIGN.md §16) — the
    materialized class scores are clipped to it before argmax, modeling the
    approximate OR-tree vote adder (+inf rows are an exact no-op).
    For forest operands the returned class is the (possibly saturated)
    majority vote over trees (ties -> lowest class index, matching
    `forest_predict`). ``block_l`` tiles the concatenated leaf axis for
    large forests.
    """
    interpret = _auto_interpret() if interpret is None else interpret
    sel, path_t, target, cls1h = pt_operands
    x8f = _pad_to(_pad_to(x8.astype(jnp.float32), block_b, 0), 128, 1)
    x8f = x8f[:, : sel.shape[0]]
    n = sel.shape[1]
    scale = _pad_to(scale, n, 1)[:, :n]
    # padded comparators must never fire: thr pad = 256 > any x_p
    thr = _pad_to(thr, n, 1, value=256.0)[:, :n]
    if block_l is not None:
        block_l = _fit_block_l(path_t.shape[1], block_l)
    scores = _ti.tree_infer_scores(
        x8f, sel, scale, thr, path_t, target, cls1h,
        block_b=block_b, block_l=block_l, interpret=interpret,
    )
    scores = scores[:, : x8.shape[0], :]
    if vote_cap is not None:
        scores = jnp.minimum(scores, vote_cap[:, None, None])
    return jnp.argmax(scores, axis=-1)


def _fit_block_l(l_pad: int, block_l: int) -> int:
    """Round ``block_l`` down to a 128-multiple that divides the padded leaf
    axis, so one configured tile size works for any forest size (128 always
    divides the padded L)."""
    block_l = max(128, (min(block_l, l_pad) // 128) * 128)
    while l_pad % block_l:
        block_l -= 128
    return block_l


# ---------------------------------------------------------------------------
# serving (DESIGN.md §14)
# ---------------------------------------------------------------------------

def prepare_design(bits, t_int, trunc=None, vote_adder: str = "exact"):
    """Fixed-design kernel operands from a decoded pareto point.

    ``bits``/``t_int`` are one design's per-comparator precisions and
    substituted integer thresholds (e.g. a `pareto.json` point's `bits` /
    `t_int` arrays) — the already-decoded form, so serving never re-rounds
    genes. ``trunc``/``vote_adder`` select the point's approximate cells
    (DESIGN.md §16); truncation is folded into the effective scale/thr
    exactly as `decode_population_full` does. Returns (scale, thr,
    vote_cap): scale/thr (1, N) f32, vote_cap (1,) f32 — the P=1 row the
    population kernels consume.
    """
    if vote_adder not in ("exact", "approx"):
        raise ValueError(f"unknown vote_adder {vote_adder!r}")
    bits = jnp.asarray(bits, jnp.int32)
    t_int = jnp.asarray(t_int, jnp.int32)
    if trunc is not None:
        k = jnp.asarray(trunc, jnp.int32)
        bits = bits - k
        t_int = jnp.right_shift(t_int, k)
    scale = jnp.exp2(-(quant.MASTER_BITS - bits).astype(jnp.float32))[None, :]
    thr = t_int.astype(jnp.float32)[None, :]
    cap = jnp.full((1,), 1.0 if vote_adder == "approx" else jnp.inf,
                   jnp.float32)
    return scale, thr, cap


def classify(x8, pt_operands, design, *, block_b=256, block_l=None,
             interpret=None):
    """(B,) predicted classes for ONE fixed tree/forest design.

    The batch-1..bucket serving entry (DESIGN.md §14): the P=1 row of
    `tree_infer_predict` over the same prepared operands, so a served
    prediction runs the exact tensor program the search scored — and the
    netlist simulator stays its bit-exact oracle. ``design`` comes from
    `prepare_design` (including the point's truncation/vote-adder
    approximation config); ``x8`` is (B, F) int master codes with B at any
    bucket size (the kernel pads the batch axis to ``block_b`` internally).
    """
    scale, thr, vote_cap = design
    return tree_infer_predict(x8, pt_operands, scale, thr, vote_cap,
                              block_b=block_b, block_l=block_l,
                              interpret=interpret)[0]


# ---------------------------------------------------------------------------
# fitness (fused fitness pipeline, DESIGN.md §12)
# ---------------------------------------------------------------------------

def prepare_fitness_operands(x_sel, y, path, path_len, n_neg,
                             leaf_class, n_classes: int):
    """Hoisted, padded operands for the fused fitness kernel.

    ``x_sel`` is the chromosome-invariant gather ``x8[:, feature]`` already
    hoisted onto the problem (`SearchProblem.x_sel` / `PaddedProblem.x_sel`,
    DESIGN.md §12) — it replaces the one-hot ``X8 @ SEL`` matmul that
    `tree_infer_scores` re-runs in every grid cell, and the per-chromosome
    comparator eval becomes a pure broadcast compare. Padding is
    correctness-preserving exactly as in `prepare_operands`: padded
    comparator columns are neutralized by the thr = 256 row padding applied
    in `fitness_errors`, padded leaves carry the unsatisfiable target -1,
    padded classes receive no votes.

    Returns ``(x_sel, path_t, target, cls1h, y_row)`` — `x_sel` (B, N) f32,
    `y_row` (1, B) f32 — with N/L/C padded to 128 multiples; the batch axis
    is padded at call time (it depends on ``block_b``).
    """
    path = np.asarray(path)
    path_len = np.asarray(path_len)
    n_neg = np.asarray(n_neg)
    leaf_class = np.asarray(leaf_class)
    l, n = path.shape
    x_sel = np.asarray(x_sel).astype(np.float32)
    path_t = path.T.astype(np.float32)                          # (N, L)
    target = (path_len - n_neg).astype(np.float32)[None]        # (1, L)
    cls1h = np.zeros((l, n_classes), np.float32)
    cls1h[np.arange(l), leaf_class] = 1.0

    x_sel = _pad_to(jnp.asarray(x_sel), 128, 1)
    path_t = _pad_to(_pad_to(jnp.asarray(path_t), 128, 0), 128, 1)
    target = _pad_to(jnp.asarray(target), 128, 1, value=-1.0)
    cls1h = _pad_to(_pad_to(jnp.asarray(cls1h), 128, 0), 128, 1)
    y_row = jnp.asarray(np.asarray(y).astype(np.float32))[None]  # (1, B)
    return x_sel, path_t, target, cls1h, y_row


@functools.partial(
    jax.jit, static_argnames=("block_p", "block_b", "block_l", "interpret")
)
def fitness_errors(fit_operands, scale, thr, vote_cap=None, *, block_p=8,
                   block_b=256, block_l=None, interpret=None):
    """(P,) misclassified-sample counts for a population of trees/forests.

    `fit_operands` from `prepare_fitness_operands` (N/L/C already padded);
    scale/thr (P, N-padded-able) f32; vote_cap (P,) f32 optional vote
    saturation (DESIGN.md §16) — the kernel clips the accumulated class
    votes to it before the on-chip argmax (+inf rows are an exact no-op,
    so omitting it IS the exact adder). Handles ragged edges internally:
    the batch axis pads to ``block_b`` with label -1 rows (never counted
    correct), the population axis pads to ``block_p`` with inert rows that
    are cropped from the result. One kernel launch computes the whole
    population x test-set x forest product and writes only the O(P)
    accumulator to HBM — `argmax(tree_infer_scores) != y` is the bit-exact
    materializing oracle (DESIGN.md §12).
    """
    interpret = _auto_interpret() if interpret is None else interpret
    x_sel, path_t, target, cls1h, y_row = fit_operands
    n_pop = scale.shape[0]
    n = x_sel.shape[1]
    x_sel_p = _pad_to(x_sel, block_b, 0)
    y_p = _pad_to(y_row, block_b, 1, value=-1.0)
    scale_p = _pad_to(_pad_to(scale, n, 1)[:, :n], block_p, 0)
    # padded comparators / chromosomes must never fire: thr pad = 256 > x_p
    thr_p = _pad_to(_pad_to(thr, n, 1, value=256.0)[:, :n],
                    block_p, 0, value=256.0)
    if vote_cap is None:
        vote_cap = jnp.full((n_pop,), jnp.inf, jnp.float32)
    # lane-replicated (P, LANES) tile; pad rows get the exact +inf cap
    vcap_p = _pad_to(jnp.broadcast_to(vote_cap[:, None].astype(jnp.float32),
                                      (n_pop, _fit.LANES)),
                     block_p, 0, value=jnp.inf)
    if block_l is not None:
        block_l = _fit_block_l(path_t.shape[1], block_l)
    counts = _fit.fitness_errors(
        x_sel_p, scale_p, thr_p, path_t, target, cls1h, y_p, vcap_p,
        block_p=block_p, block_b=block_b, block_l=block_l,
        interpret=interpret,
    )
    n_valid = jnp.sum((y_row >= 0).astype(jnp.float32))
    return n_valid - counts[:n_pop, 0]


# ---------------------------------------------------------------------------
# domination
# ---------------------------------------------------------------------------

def _dom_block_size(p, block):
    return min(block, max(128, 1 << (p - 1).bit_length()))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def domination_block(objs_i, objs_j, *, block=256, interpret=None):
    """(Pi, Pj) f32 rectangular domination slab; accepts any Pi/Pj (pads
    internally).

    The sharded-sort entry point (DESIGN.md §13): ``objs_i`` is a shard's
    local population slab (rows), ``objs_j`` the all-gathered pool (columns).
    Padding rows/columns are +inf objectives — pad rows never dominate
    anything real, and pad columns (which real rows trivially dominate) are
    cropped before return.
    """
    interpret = _auto_interpret() if interpret is None else interpret
    pi, pj = objs_i.shape[0], objs_j.shape[0]
    bi, bj = _dom_block_size(pi, block), _dom_block_size(pj, block)
    oi = _pad_to(objs_i.astype(jnp.float32), bi, 0, value=jnp.inf)
    oj = _pad_to(objs_j.astype(jnp.float32), bj, 0, value=jnp.inf)
    dom = _dom.domination_block(oi, oj, block_i=bi, block_j=bj,
                                interpret=interpret)
    return dom[:pi, :pj]


def domination_block_bool(objs_i, objs_j, *, interpret=None):
    """Adapter with the core.nsga2 rectangular signature (bool output)."""
    return domination_block(objs_i, objs_j, interpret=interpret) > 0.5


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def domination_matrix(objs, *, block=256, interpret=None):
    """(P, P) f32 domination matrix; accepts any P (pads internally).

    Padding rows are +inf objectives: they never dominate anything real and
    the returned matrix is cropped back to (P, P).
    """
    return domination_block(objs, objs, block=block, interpret=interpret)


def domination_matrix_bool(objs, *, interpret=None):
    """Adapter with the core.nsga2 signature (bool output)."""
    return domination_matrix(objs, interpret=interpret) > 0.5


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def qmatmul(x, w_q, scale, *, block_m=256, block_n=256, block_k=512,
            interpret=None):
    """Mixed-precision matmul with padding to MXU tiles.

    x (M, K) f32/bf16; w_q (K, N) int8 codes; scale (N,) or (1, N) f32.
    Returns (M, N) f32.
    """
    interpret = _auto_interpret() if interpret is None else interpret
    m, k = x.shape
    _, n = w_q.shape
    scale = scale.reshape(1, -1)
    bm, bn, bk = (min(block_m, _ceil_mult(m)), min(block_n, _ceil_mult(n)),
                  min(block_k, _ceil_mult(k)))
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w_q, bk, 0), bn, 1)
    sp = _pad_to(scale, bn, 1)
    out = _qmm.qmatmul(xp, wp, sp, block_m=bm, block_n=bn, block_k=bk,
                       interpret=interpret)
    return out[:m, :n]


def _ceil_mult(size, base=128):
    """Smallest multiple of `base` >= min(size_rounded, base*8)."""
    r = ((size + base - 1) // base) * base
    return max(base, min(r, base * 8))
