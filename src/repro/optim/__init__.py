from repro.optim.optimizers import (
    adamw, adafactor, get_optimizer, clip_by_global_norm,
    warmup_cosine_schedule,
)
from repro.optim import compress

__all__ = [
    "adamw", "adafactor", "get_optimizer", "clip_by_global_norm",
    "warmup_cosine_schedule", "compress",
]
