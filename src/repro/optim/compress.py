"""int8 gradient compression for cross-pod reduction (DESIGN.md §6).

Cross-pod ICI/DCN links are the scarcest bandwidth at multi-pod scale. The
hierarchical scheme: GSPMD reduces gradients *within* a pod at full precision
(implicit in the sharded train step); the *cross-pod* reduction runs through
`compressed_psum` inside a shard_map over the 'pod' axis — int8 codes + one
f32 scale per tensor, a 4x byte reduction on the slowest links.

Quantization is symmetric per-tensor: q = round(g / s), s = max|g| / 127,
summed in int32 (pod counts are tiny: no overflow below 2^23 / 127 pods).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def quantize_int8(g):
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis: str):
    """psum a gradient pytree across `axis` with int8 payload.

    Each participant quantizes with its own scale; scales are maxed across
    the axis first so codes are commensurable (one extra scalar all-reduce).
    """
    def one(g):
        scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0
        scale = jax.lax.pmax(jnp.maximum(scale, 1e-30), axis)
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        n = jax.lax.psum(1, axis)
        return (total.astype(jnp.float32) * scale / n).astype(g.dtype)

    return jax.tree.map(one, grads)


def make_crosspod_mean(mesh, axis: str = "pod"):
    """Returns fn(grads)->grads averaging across pods with int8 payload.

    grads are assumed replicated across `axis` shards *within* each pod
    already (the in-pod reduction is full precision, done by GSPMD)."""
    other = tuple(n for n in mesh.axis_names if n != axis)

    def spec_for(g):
        return P()  # replicated entering the wrapper; shard_map splits axis

    @partial(shard_map, mesh=mesh, in_specs=(P(),), out_specs=P(),
             check_rep=False)
    def _mean(g):
        return compressed_psum(g, axis)

    return _mean
