"""Optimizers as pure (init, update) pairs on pytrees — no external deps.

AdamW keeps f32 first/second moments (sharded like the params); Adafactor
factorizes the second moment over the two trailing dims of >=2D params, the
standard choice for the 314B/1T MoE configs where Adam states exceed HBM
(DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)
    name: str = ""


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


def warmup_cosine_schedule(peak_lr: float, warmup: int, total: int,
                           floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01,
          schedule=None):
    lr_fn = schedule or (lambda step: jnp.float32(lr))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            mh = m / (1 - b1 ** stepf)
            vh = v / (1 - b2 ** stepf)
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update, "adamw")


def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0, schedule=None):
    lr_fn = schedule or (lambda step: jnp.float32(lr))

    def init(params):
        def state_for(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(state_for, params)

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        beta = 1.0 - stepf ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True)[..., None], eps)) * vc[..., None, :]
                upd_ = gf * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd_ = gf * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd_)) + 1e-30)
            upd_ = upd_ / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr_t * (
                upd_ + weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), new_s

        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state)
        flat_p = tdef.flatten_up_to(params)
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_state = tdef.unflatten([o[1] for o in outs])
        return new_params, new_state

    return Optimizer(init, update, "adafactor")


def get_optimizer(cfg, schedule=None):
    if cfg.optimizer == "adafactor":
        return adafactor(schedule=schedule)
    return adamw(schedule=schedule)
