"""Synthetic stand-ins for the paper's 10 UCI classification datasets.

Each generator is deterministic (fixed seed derived from the dataset name) and
matches the UCI dataset's (n_samples, n_features, n_classes) signature plus a
coarse notion of its feature discreteness (Balance/Mammographic are small-
integer-valued in UCI, which is what makes their bespoke comparators cheap in
the paper's Table I).

Data is a mixture of class-conditional Gaussian clusters over an informative
subspace, plus label noise to emulate each dataset's intrinsic difficulty
(paper Table I accuracies span 0.56..0.97).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_samples: int
    n_features: int
    n_classes: int
    n_informative: int          # features that actually carry signal
    clusters_per_class: int = 1
    class_sep: float = 1.0      # separation of cluster centers (in sigma units)
    label_noise: float = 0.0    # fraction of labels re-drawn uniformly
    integer_levels: int | None = None  # quantize features to k levels (UCI-like)
    paper_accuracy: float = 0.0  # paper Table I DT accuracy, for reference


# Signatures follow the UCI originals; class_sep / label_noise are tuned so a
# fully-grown CART lands in the neighbourhood of the paper's Table I accuracy.
# class_sep / label_noise grid-tuned (benchmarks) so a fully-grown CART's
# test accuracy lands near the paper's Table I per-dataset accuracy.
DATASET_SPECS: dict[str, DatasetSpec] = {
    "arrhythmia": DatasetSpec("arrhythmia", 452, 279, 13, 24, 1, 2.6, 0.10, None, 0.564),
    "balance": DatasetSpec("balance", 625, 4, 3, 4, 2, 2.4, 0.05, 5, 0.745),
    "cardio": DatasetSpec("cardio", 2126, 21, 3, 10, 2, 2.6, 0.015, None, 0.928),
    "har": DatasetSpec("har", 10299, 561, 6, 40, 2, 3.2, 0.08, None, 0.835),
    "mammographic": DatasetSpec("mammographic", 961, 5, 2, 4, 1, 2.6, 0.10, 6, 0.759),
    "pendigits": DatasetSpec("pendigits", 10992, 16, 10, 14, 2, 4.2, 0.001, None, 0.968),
    "redwine": DatasetSpec("redwine", 1599, 11, 6, 8, 1, 2.8, 0.22, None, 0.600),
    "seeds": DatasetSpec("seeds", 210, 7, 3, 6, 1, 2.4, 0.02, None, 0.889),
    "vertebral": DatasetSpec("vertebral", 310, 6, 3, 5, 1, 2.2, 0.04, None, 0.850),
    "whitewine": DatasetSpec("whitewine", 4898, 11, 7, 8, 1, 3.0, 0.20, None, 0.617),
}


@dataclasses.dataclass
class Dataset:
    name: str
    x_train: np.ndarray  # float32 in [0, 1]
    y_train: np.ndarray  # int32
    x_test: np.ndarray
    y_test: np.ndarray
    n_classes: int

    @property
    def n_features(self) -> int:
        return self.x_train.shape[1]


def _seed_for(name: str) -> int:
    return int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "little")


def _generate(spec: DatasetSpec) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(_seed_for(spec.name))
    n, d, c = spec.n_samples, spec.n_features, spec.n_classes
    n_inf = min(spec.n_informative, d)

    # cluster centers for every (class, cluster) on the informative subspace
    centers = rng.uniform(-1.0, 1.0, size=(c, spec.clusters_per_class, n_inf))
    centers *= spec.class_sep

    y = rng.integers(0, c, size=n).astype(np.int32)
    which = rng.integers(0, spec.clusters_per_class, size=n)
    x = rng.normal(0.0, 1.0, size=(n, d)).astype(np.float64)
    x[:, :n_inf] += centers[y, which]

    # a random rotation inside the informative block makes single-feature
    # splits non-trivial (like real tabular data)
    q, _ = np.linalg.qr(rng.normal(size=(n_inf, n_inf)))
    x[:, :n_inf] = x[:, :n_inf] @ q

    noise_mask = rng.random(n) < spec.label_noise
    y[noise_mask] = rng.integers(0, c, size=int(noise_mask.sum()))

    if spec.integer_levels is not None:
        # emulate small-integer UCI features (Balance: 1..5, Mammographic bins)
        lo, hi = np.percentile(x, [1, 99], axis=0)
        x = np.clip((x - lo) / np.maximum(hi - lo, 1e-9), 0.0, 1.0)
        k = spec.integer_levels
        x = np.round(x * (k - 1)) / (k - 1)
    return x.astype(np.float32), y


def train_test_split(
    x: np.ndarray, y: np.ndarray, test_fraction: float = 0.3, seed: int = 0
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Random split; paper uses a random 30% test split."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    perm = rng.permutation(n)
    n_test = int(round(n * test_fraction))
    te, tr = perm[:n_test], perm[n_test:]
    return x[tr], y[tr], x[te], y[te]


def _normalize01(x_train: np.ndarray, x_test: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Min-max normalize to [0, 1] using *train* statistics (paper §IV)."""
    lo = x_train.min(axis=0)
    hi = x_train.max(axis=0)
    span = np.maximum(hi - lo, 1e-9)
    xt = np.clip((x_train - lo) / span, 0.0, 1.0)
    xe = np.clip((x_test - lo) / span, 0.0, 1.0)
    return xt.astype(np.float32), xe.astype(np.float32)


def quantize_u8(x: np.ndarray, bits: int = 8) -> np.ndarray:
    """Master fixed-point grid: x in [0,1] -> integer in [0, 2^bits - 1].

    floor-based truncation; 1.0 maps to the top code. All lower precisions are
    right-shifts of this master code (see core.quant).
    """
    scale = float(1 << bits)
    xi = np.floor(x * scale).astype(np.int64)
    return np.clip(xi, 0, (1 << bits) - 1).astype(np.uint8)


def load_dataset(name: str, test_fraction: float = 0.3, seed: int = 0) -> Dataset:
    spec = DATASET_SPECS[name]
    x, y = _generate(spec)
    xtr, ytr, xte, yte = train_test_split(x, y, test_fraction, seed)
    xtr, xte = _normalize01(xtr, xte)
    return Dataset(name, xtr, ytr, xte, yte, spec.n_classes)
