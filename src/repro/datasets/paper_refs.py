"""The source paper's published per-dataset results (Tables I & II).

These are the scoring targets for the full-suite sweep campaign
(`repro.search.sweep`, DESIGN.md §11) and for `benchmarks/paper_tables.py`
— kept in the package (not under benchmarks/) so `python -m repro.search
sweep --report` can score a run without the benchmarks tree on sys.path.

All paper areas/powers are Synopsys-DC/EGT-PDK measurements; this repo's
area model is gate-count based and calibrated to the same magnitudes
(DESIGN.md §4), so per-dataset *normalized* quantities (Table II) are the
meaningful comparison and absolute mm^2 are order-of-magnitude checks.
"""
from __future__ import annotations

# dataset: (accuracy, n_comparators, delay_ms, area_mm2, power_mw)
PAPER_TABLE1: dict[str, tuple[float, int, float, float, float]] = {
    "arrhythmia": (0.564, 54, 27.0, 162.50, 7.55),
    "balance": (0.745, 102, 28.0, 68.04, 3.11),
    "cardio": (0.928, 79, 30.4, 178.63, 8.12),
    "har": (0.835, 178, 33.7, 551.08, 26.10),
    "mammographic": (0.759, 150, 34.2, 98.75, 4.47),
    "pendigits": (0.968, 243, 36.9, 574.46, 25.00),
    "redwine": (0.600, 259, 38.7, 513.84, 22.30),
    "seeds": (0.889, 10, 20.3, 30.13, 1.43),
    "vertebral": (0.850, 27, 20.9, 57.70, 2.68),
    "whitewine": (0.617, 280, 49.9, 543.12, 23.20),
}

# dataset: (normalized area, normalized power) of the paper's selected
# approximate design at the 1% accuracy-loss budget (Table II)
PAPER_TABLE2_NORM: dict[str, tuple[float, float]] = {
    "arrhythmia": (0.137, 0.138), "balance": (0.401, 0.372),
    "cardio": (0.244, 0.253), "har": (0.534, 0.525),
    "mammographic": (0.082, 0.084), "pendigits": (0.641, 0.644),
    "redwine": (0.520, 0.525), "seeds": (0.077, 0.064),
    "vertebral": (0.136, 0.142), "whitewine": (0.229, 0.230),
}

# cross-dataset means the paper headlines at the 1% budget
PAPER_MEAN_AREA_REDUCTION_1PCT = 3.2
PAPER_MEAN_POWER_REDUCTION_1PCT = 3.4
