"""Dataset substrate.

The paper evaluates on 10 UCI datasets. UCI is unreachable offline, so this
package generates deterministic synthetic datasets with the *same signature*
(n_samples, n_features, n_classes, feature discreteness) as each UCI dataset.
Relative claims (area/power reduction at bounded accuracy loss) are scale-free
w.r.t. the exact data distribution; see DESIGN.md §2.
"""
from repro.datasets.synthetic import (
    DATASET_SPECS,
    Dataset,
    load_dataset,
    train_test_split,
    quantize_u8,
)

__all__ = [
    "DATASET_SPECS",
    "Dataset",
    "load_dataset",
    "train_test_split",
    "quantize_u8",
]
