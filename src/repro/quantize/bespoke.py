"""The paper's dual approximation generalized to LM weights (DESIGN.md §5).

Per quantizable tensor, two genes — exactly the comparator chromosome layout:
  precision gene  -> bits in [2, 8]   (symmetric per-output-channel codes)
  margin gene     -> snap window m in [0, 5]

Hardware-friendly snapping: each integer code moves (within +/-m) to the code
with minimal CSD-like multiplier cost — popcount(|code|) — mirroring the
paper's move-threshold-to-cheap-bit-pattern. In bespoke/printed MACs (and in
shift-add TPU-adjacent datapaths) the multiplier cost tracks the number of
non-zero bits of the constant; the analogue of the paper's Fig. 4 LUT.

Objectives (both minimized, as in the paper):
  f1 = quantized-model CE loss - float CE loss   (accuracy loss)
  f2 = sum_t size_t * (alpha * bits_t + popcount cost) / float_cost

The quantized forward executes through kernels.qmatmul (int8 codes + scales),
so the search optimizes exactly what the serving path runs.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import quant as core_quant


@functools.lru_cache(maxsize=64)
def snap_lut(bits: int, margin: int) -> np.ndarray:
    """code (two's complement int in [-2^(b-1), 2^(b-1)-1]) -> snapped code.

    The single-step snap is iterated to a FIXPOINT at build time: one pass
    can land on a code that itself snaps cheaper (e.g. bits=8, m=2:
    19 -> 18 (popcount 2) -> 16 (popcount 1)), which would make snapping
    non-idempotent — re-snapping already-snapped weights (as the printed-MLP
    family's decode does through its precision ladder) would then drift.
    Iteration terminates because popcount(|snap(c)|) <= popcount(|c|) with
    ties broken by smaller |step|=0, so each chase strictly reduces the
    (popcount, |c|) key; margin=0 stays the identity and codes never leave
    [lo, hi] (property-tested in tests/test_quantize.py)."""
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    out = np.zeros(1 << bits, dtype=np.int32)
    for code in range(lo, hi + 1):
        best, best_key = code, (bin(abs(code)).count("1"), 0)
        for d in range(-margin, margin + 1):
            c = code + d
            if c < lo or c > hi:
                continue
            key = (bin(abs(c)).count("1"), abs(d))
            if key < best_key:
                best, best_key = c, key
        out[code - lo] = best
    # chase each snap chain to its fixpoint so snap(snap(c)) == snap(c);
    # the chain length is bounded by the strictly-decreasing key, but cap
    # the walk at the table size anyway
    for idx in range(out.shape[0]):
        for _ in range(out.shape[0]):
            nxt = int(out[int(out[idx]) - lo])
            if nxt == int(out[idx]):
                break
            out[idx] = nxt
    return out  # index by (code - lo)


def quantize_tensor(w, bits: int, margin: int):
    """w (.., K, N) float -> (codes int8, scale (.., 1, N) f32)."""
    wf = np.asarray(w, np.float32)
    amax = np.max(np.abs(wf), axis=-2, keepdims=True)
    scale = np.maximum(amax, 1e-9) / ((1 << (bits - 1)) - 1)
    codes = np.clip(np.round(wf / scale), -(1 << (bits - 1)),
                    (1 << (bits - 1)) - 1).astype(np.int32)
    if margin > 0:
        lut = snap_lut(bits, margin)
        codes = lut[codes + (1 << (bits - 1))]
    return codes.astype(np.int8), scale.astype(np.float32)


def dequantize_tensor(codes, scale):
    return codes.astype(np.float32) * scale


def tensor_cost(codes, bits: int, alpha: float = 0.5) -> float:
    """Mixed memory (bits) + multiplier (popcount) cost, per tensor."""
    pop = np.unpackbits(np.abs(codes.astype(np.int16)).astype(np.uint8)
                        [..., None], axis=-1).sum()
    return alpha * codes.size * bits / 8.0 + (1 - alpha) * float(pop) / 8.0


def quantizable_tensors(params) -> list[tuple[str, tuple]]:
    """All >=2D weight tensors (matmul operands) with their tree paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if leaf.ndim >= 2 and "norm" not in name and "conv" not in name:
            out.append((name, path))
    return out


def apply_chromosome(params, genes: np.ndarray):
    """Decode (2T,) genes and return (quantized params, total cost).

    Quantization-aware float emulation: weights are replaced by their
    dequantized values, so any model forward evaluates the approximate
    network (and kernels.qmatmul runs the same codes at serving time).
    """
    tensors = quantizable_tensors(params)
    span_p = core_quant.MAX_BITS - core_quant.MIN_BITS + 1
    bits = (core_quant.MIN_BITS
            + np.clip(np.floor(genes[0::2] * span_p), 0, span_p - 1)
            ).astype(int)
    margins = np.clip(np.floor(genes[1::2] * 6), 0, 5).astype(int)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    name_to_idx = {}
    for i, (name, _) in enumerate(tensors):
        name_to_idx[name] = i
    new_leaves = []
    total_cost = 0.0
    float_cost = 0.0
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if name in name_to_idx:
            i = name_to_idx[name]
            codes, scale = quantize_tensor(leaf, int(bits[i]), int(margins[i]))
            total_cost += tensor_cost(codes, int(bits[i]))
            float_cost += leaf.size * 2.0  # bf16 bytes baseline
            new_leaves.append(jnp.asarray(dequantize_tensor(codes, scale),
                                          dtype=leaf.dtype))
        else:
            new_leaves.append(leaf)
    qparams = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return qparams, total_cost / max(float_cost, 1e-9)


def make_lm_quant_problem(params, cfg, batch, loss_fn):
    """Fitness closure for NSGA-II over per-tensor (bits, margin) genes."""
    base_loss = float(loss_fn(params, batch))
    n_tensors = len(quantizable_tensors(params))

    def fitness_np(pop: np.ndarray) -> np.ndarray:
        objs = np.zeros((pop.shape[0], 2), np.float32)
        for i, genes in enumerate(pop):
            qparams, cost = apply_chromosome(params, np.asarray(genes))
            loss = float(loss_fn(qparams, batch))
            objs[i] = (loss - base_loss, cost)
        return objs

    return fitness_np, 2 * n_tensors, base_loss
