from repro.quantize.bespoke import (
    snap_lut, quantize_tensor, dequantize_tensor, tensor_cost,
    quantizable_tensors, make_lm_quant_problem, apply_chromosome,
)

__all__ = [
    "snap_lut", "quantize_tensor", "dequantize_tensor", "tensor_cost",
    "quantizable_tensors", "make_lm_quant_problem", "apply_chromosome",
]
