"""mamba2-1.3b [ssm]: 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060].

Pure Mamba2: expand=2 (d_inner=4096), head_dim=64 (64 SSD heads), conv=4,
single B/C group. Vocab padded 50280 -> 50304 for TP divisibility.
Sub-quadratic: runs the long_500k shape.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
)
