"""Architecture registry: one module per assigned architecture (+ the paper's
own DT-GA workload). `get_config(name)` / `--arch <id>` select them."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, shapes_for

ARCH_IDS = [
    "musicgen-large",
    "paligemma-3b",
    "mamba2-1.3b",
    "llama3.2-3b",
    "gemma-2b",
    "minitron-8b",
    "command-r-35b",
    "kimi-k2-1t-a32b",
    "grok-1-314b",
    "zamba2-7b",
]

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[name]}")
    return mod.CONFIG


def reduced_config(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Small same-family config for CPU smoke tests."""
    small = dict(
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        loss_chunk=64,
    )
    if cfg.n_heads:
        small.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2))
    if cfg.family == "moe":
        small.update(n_experts=4, experts_per_token=2, moe_d_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=16, ssm_head_dim=16)
    if cfg.family == "hybrid":
        small.update(n_layers=6, shared_attn_every=3, n_shared_blocks=2)
    if cfg.prefix_len:
        small.update(prefix_len=8)
    small.update(dtype="float32", grad_accum=1)
    if cfg.n_experts:
        small.update(moe_a2a_int8=False)  # smoke tests stay bit-deterministic
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "ARCH_IDS",
    "get_config", "reduced_config", "shapes_for",
]
