"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242].

81 Mamba2 layers with 2 *shared* attention+MLP blocks invoked every 6 layers
(alternating), per the Zamba2 scheme (per-invocation LoRA deltas omitted —
DESIGN.md §8). Sub-quadratic backbone: runs the long_500k shape (the shared
attention blocks carry real 500k KV caches — the honest cost).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    act="swiglu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    shared_attn_every=6,
    n_shared_blocks=2,
)
