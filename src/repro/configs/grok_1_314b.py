"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) expert_ff=32768
vocab=131072, MoE 8 experts top-2 [hf:xai-org/grok-1].

GeLU experts, tanh attention-logit softcap (grok-style). 8 experts < 16-way
model axis: expert d_ff shards over (data, model) = 256-way (DESIGN.md §5).
Adafactor for the same HBM reasons as kimi.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131_072,
    act="gelu",
    attn_softcap=30.0,
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=32768,
    optimizer="adafactor",
    grad_accum=4,
    grad_accum_dtype="bfloat16",
)
