"""musicgen-large [audio]: 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only per assignment: the EnCodec frontend is a stub providing frame
embeddings; the 4-codebook delay pattern is flattened to one token stream
(DESIGN.md §8). Standard pre-LN transformer, GELU MLP, LayerNorm.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    norm="layer",
    frontend="audio_frames",
)
