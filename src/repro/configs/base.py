"""Architecture config system. One frozen dataclass drives model init,
sharding rules, train/serve steps and the dry-run."""
from __future__ import annotations

import dataclasses


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free archs
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    act: str = "swiglu"          # swiglu | geglu | gelu | relu2
    norm: str = "rms"            # rms | rms1p (gemma) | layer
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    attn_softcap: float = 0.0    # grok-style tanh logit capping
    embed_scale: bool = False    # gemma multiplies embeddings by sqrt(d)

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_a2a_int8: bool = False  # quantize dispatch payload (wire bytes /2)

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4

    # hybrid (zamba2): shared attention blocks interleaved among SSM layers
    shared_attn_every: int = 0   # 0 = no shared blocks
    n_shared_blocks: int = 0

    # modality frontend stubs ([audio]/[vlm] per assignment)
    frontend: str | None = None  # None | "audio_frames" | "vision_patches"
    prefix_len: int = 0          # vlm: number of patch-embedding positions

    # training knobs
    dtype: str = "bfloat16"
    remat: bool = True
    grad_accum: int = 1
    grad_accum_dtype: str = "float32"  # bf16 for the MoE giants (HBM)
    optimizer: str = "adamw"     # adamw | adafactor
    loss_chunk: int = 2048       # sequence chunking for the CE loss

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, 128)

    @property
    def is_ssm_layer_arch(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def n_params_dense_estimate(self) -> int:
        """Rough parameter count (embeddings + blocks), for roofline N."""
        d = self.d_model
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("ssm", "hybrid"):
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_nheads
            conv_ch = di + 2 * ns
            per_layer = d * (2 * di + 2 * ns + nh) + conv_ch * self.ssm_conv \
                + di * d + 3 * nh + di
        if self.n_heads:
            attn = d * self.n_heads * self.head_dim * 2 \
                + d * self.n_kv_heads * self.head_dim * 2
            if self.family == "hybrid":
                pass  # shared blocks counted separately below
            else:
                per_layer += attn
        if self.family == "moe":
            ff_mults = 3 if self.act in ("swiglu", "geglu") else 2
            per_layer += d * self.moe_d_ff * ff_mults * self.n_experts
            per_layer += d * self.n_experts  # router
        elif self.family != "ssm" and self.d_ff:
            ff_mults = 3 if self.act in ("swiglu", "geglu") else 2
            per_layer += d * self.d_ff * ff_mults
        total = emb + self.n_layers * per_layer
        if self.family == "hybrid" and self.n_shared_blocks:
            attn = d * self.n_heads * self.head_dim * 2 \
                + d * self.n_kv_heads * self.head_dim * 2
            ff_mults = 3 if self.act in ("swiglu", "geglu") else 2
            total += self.n_shared_blocks * (attn + d * self.d_ff * ff_mults)
        return total

    @property
    def n_params_active_estimate(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.n_params_dense_estimate
        d = self.d_model
        ff_mults = 3 if self.act in ("swiglu", "geglu") else 2
        dense = self.n_params_dense_estimate - (
            self.n_layers * d * self.moe_d_ff * ff_mults * self.n_experts
        )
        return dense + self.n_layers * d * self.moe_d_ff * ff_mults * self.experts_per_token

    @property
    def n_params_compute_estimate(self) -> int:
        """Params-equivalent per-token compute (hybrid: shared blocks run
        once per super-block, not once per stored copy)."""
        base = self.n_params_active_estimate
        if self.family == "hybrid" and self.n_shared_blocks:
            d = self.d_model
            attn = d * self.n_heads * self.head_dim * 2 \
                + d * self.n_kv_heads * self.head_dim * 2
            ff_mults = 3 if self.act in ("swiglu", "geglu") else 2
            per_block = attn + d * self.d_ff * ff_mults
            n_super = self.n_layers // max(self.shared_attn_every, 1)
            base += per_block * (n_super - self.n_shared_blocks)
        return base


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM family (all 10 archs share these)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic sequence mixing (see DESIGN.md shape-skips)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shapes_for(cfg: ArchConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in SUBQUADRATIC_FAMILIES:
        out.append("long_500k")
    return out
