"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3 [hf:meta-llama/Llama-3.2-3B].

SwiGLU, RoPE theta 500k, tied embeddings. n_heads=24 is not divisible by the
16-way model axis: baseline uses the replicated-attention path (DESIGN.md §5)
— a recorded hillclimb lever.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128_256,
    act="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
)
