"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP + gemma [arXiv:2407.07726; hf].

Gemma-2b text backbone with a 256-position SigLIP patch-embedding prefix
(frontend is a stub per assignment). MQA (kv=1), GeGLU, head_dim 256,
gemma-style (1+w) RMSNorm and sqrt(d) embedding scaling.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257_216,
    act="geglu",
    norm="rms1p",
    embed_scale=True,
    frontend="vision_patches",
    prefix_len=256,
)
