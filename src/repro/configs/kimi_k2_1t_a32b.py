"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) expert_ff=2048
vocab=163840, MoE 384 experts top-8 — trillion-param MoE [arXiv:2501.kimi2].

~1.03T total / ~30B active parameters. Trains with Adafactor: Adam fp32
states would exceed v5e HBM at 512 chips (DESIGN.md §5). grad_accum=4 keeps
per-microbatch activations bounded and overlaps the grad reduce-scatter.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,           # unused for moe blocks (kept for reference)
    vocab_size=163_840,
    act="swiglu",
    n_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    capacity_factor=1.0,   # §Perf: a2a wire bytes scale with C
    moe_a2a_int8=True,     # §Perf: int8 dispatch payload
    optimizer="adafactor",
    grad_accum=4,
    grad_accum_dtype="bfloat16",
)
