import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module (before
any jax import): jax locks the device count at first init, and the dry-run
needs 512 placeholder host devices to build the production meshes
(16x16 single-pod, 2x16x16 multi-pod). Smoke tests and benches import other
modules and keep seeing 1 device.

Per cell this:
  1. builds ShapeDtypeStruct stand-ins for params/optimizer/caches/batch
     (no allocation),
  2. jit-lowers the step (train_step / prefill_step / serve_step) with the
     sharding spec trees from repro.sharding.params,
  3. compiled = lowered.compile()  — sharding mismatches / OOM / unsupported
     collectives fail HERE, which is the point,
  4. records memory_analysis(), cost_analysis() and an HLO collective-bytes
     breakdown into benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json.

Resumable: existing result files are skipped unless --force.
"""
import argparse
import json
import re
import time
import traceback

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shapes_for
from repro.data.pipeline import make_batch_specs
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models import lm, transformer
from repro.optim import get_optimizer
from repro.runtime import train as train_rt
from repro.runtime import lm_serve as serve_rt
from repro.sharding import params as sp

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")


def _bytes_of_shapes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective op kind (per-device HLO)."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        rhs = stripped.split("=", 1)[1]
        for kind in _COLLECTIVES:
            # result type sits between '=' and the ' kind(' occurrence
            m = re.search(rf"\s{kind}(-start)?\(", rhs)
            if m:
                stats[kind]["count"] += 1
                stats[kind]["bytes"] += _bytes_of_shapes(rhs[: m.start()])
                break
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _sds(shape_tree, shard_tree):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shape_tree, shard_tree)


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, arg_sds tuple, donate) for the cell."""
    cfg = get_config(arch)
    rules = rules_for(mesh)
    shape_cfg = SHAPES[shape_name]

    params_shapes = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg), jax.random.PRNGKey(0))
    pspecs = sp.param_specs(cfg, rules, mesh)

    if shape_cfg.kind == "train":
        opt = get_optimizer(cfg)
        state_shapes = jax.eval_shape(
            lambda p: train_rt.init_train_state(p, opt), params_shapes)
        sspecs = sp.train_state_specs(cfg, rules, mesh, opt.name)
        batch_shapes = make_batch_specs(cfg, shape_cfg)
        bspecs = sp.batch_specs(cfg, rules, mesh, batch_shapes)
        fn = train_rt.make_train_step(cfg, rules=rules, optimizer=opt)
        args = (_sds(state_shapes, _named(mesh, sspecs)),
                _sds(batch_shapes, _named(mesh, bspecs)))
        return fn, args, (0,)

    if shape_cfg.kind == "prefill":
        batch_shapes = make_batch_specs(cfg, shape_cfg)
        bspecs = sp.batch_specs(cfg, rules, mesh, batch_shapes)
        fn = serve_rt.make_prefill_step(cfg, rules=rules)
        args = (_sds(params_shapes, _named(mesh, pspecs)),
                _sds(batch_shapes, _named(mesh, bspecs)))
        return fn, args, ()

    # decode: one token against an s_max cache
    b, s_max = shape_cfg.global_batch, shape_cfg.seq_len
    cache_shapes = jax.eval_shape(
        lambda: lm.init_caches(cfg, b, s_max, rules=rules))
    cspecs = sp.cache_specs(cfg, rules, mesh, cache_shapes)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_spec = sp.batch_specs(cfg, rules, mesh, {"t": token})["t"]
    fn = serve_rt.make_serve_step(cfg, rules=rules)
    args = (_sds(params_shapes, _named(mesh, pspecs)),
            jax.ShapeDtypeStruct(token.shape, token.dtype,
                                 sharding=NamedSharding(mesh, tok_spec)),
            _sds(cache_shapes, _named(mesh, cspecs)),
            jax.ShapeDtypeStruct((), jnp.int32))
    return fn, args, (2,)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    out_path = os.path.join(out_dir, mesh_name, f"{arch}__{shape_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=multi_pod)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "n_devices": int(np.prod(list(mesh.shape.values())))}
    t0 = time.time()
    try:
        fn, args, donate = build_cell(arch, shape_name, mesh)
        with mesh:
            jitted = jax.jit(fn, donate_argnums=donate)
            lowered = jitted.lower(*args)
            record["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = round(time.time() - t1, 2)
            mem = compiled.memory_analysis()
            record["memory"] = {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
                "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
            }
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            record["cost"] = {
                "flops": float(cost.get("flops", -1)),
                "bytes_accessed": float(cost.get("bytes accessed", -1)),
            }
            hlo = compiled.as_text()
            record["collectives"] = collective_stats(hlo)
            # loop-aware accounting: XLA cost_analysis counts while bodies
            # once; scanned layer stacks need trip-count multipliers
            # (repro.launch.hlo_analysis, validated in EXPERIMENTS.md)
            from repro.launch import hlo_analysis
            la = hlo_analysis.analyze(hlo)
            record["loop_aware"] = {
                "flops": la["flops"],
                "bytes": la["bytes"],
                "collectives": la["collectives"],
            }
            # keep the compressed HLO so analyses can rerun without
            # recompiling (the hillclimb loop's "profile")
            import gzip
            hlo_path = out_path.replace(".json", ".hlo.gz")
            with gzip.open(hlo_path, "wt") as hf:
                hf.write(hlo)
            record["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — failures are data here
        record["status"] = "fail"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def run_ga_cell(multi_pod: bool, out_dir: str, force: bool = False) -> dict:
    """The paper's own workload at production scale: one island-model
    NSGA-II round (local generations + ring migration) for a HAR-scale
    approximate-DT search, one island per data-rank (256/512 chips)."""
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    out_path = os.path.join(out_dir, mesh_name, "paper-dt-ga__islands.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    from repro.datasets import load_dataset
    from repro.core.train import train_tree
    from repro.core.tree import to_parallel
    from repro.core import approx, dist, nsga2

    mesh = make_production_mesh(multi_pod=multi_pod)
    record = {"arch": "paper-dt-ga", "shape": "islands", "mesh": mesh_name,
              "n_devices": int(np.prod(list(mesh.shape.values())))}
    t0 = time.time()
    try:
        ds = load_dataset("pendigits")
        tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
        pt = to_parallel(tree)
        prob = approx.build_problem(pt, ds.x_test, ds.y_test)
        fit = approx.make_fitness_fn(prob)
        cfg = dist.IslandConfig(local_pop=32, migrate_every=4, n_migrate=4)
        n_islands = mesh.shape["data"]
        step = dist.make_island_step(fit, mesh, cfg, axis="data")
        total = n_islands * cfg.local_pop
        state_sds = nsga2.NSGA2State(
            genes=jax.ShapeDtypeStruct((total, prob.n_genes), jnp.float32),
            objs=jax.ShapeDtypeStruct((total, 2), jnp.float32),
            rank=jax.ShapeDtypeStruct((total,), jnp.int32),
            crowd=jax.ShapeDtypeStruct((total,), jnp.float32),
            key=jax.ShapeDtypeStruct((n_islands, 2), jnp.uint32),
            generation=jax.ShapeDtypeStruct((), jnp.int32),
        )
        with mesh:
            lowered = step.lower(state_sds)
            record["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = round(time.time() - t1, 2)
            mem = compiled.memory_analysis()
            record["memory"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
            }
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            record["cost"] = {"flops": float(cost.get("flops", -1))}
            record["collectives"] = collective_stats(compiled.as_text())
            record["n_comparators"] = pt.n_comparators
            record["global_population"] = total
            record["status"] = "ok"
    except Exception as e:  # noqa: BLE001
        record["status"] = "fail"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    choices=ARCH_IDS + ["all", "paper-dt-ga"])
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out_dir = args.out or os.path.abspath(RESULTS_DIR)

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.arch == "paper-dt-ga":
        for mp in meshes:
            rec = run_ga_cell(mp, out_dir, force=args.force)
            print(f"[{'OK' if rec['status'] == 'ok' else 'FAIL'}]   "
                  f"paper-dt-ga islands {rec['mesh']} "
                  f"{rec.get('error', '')[:140]}", flush=True)
        return
    archs = ARCH_IDS if args.arch in (None, "all") else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shapes = shapes_for(cfg) if args.shape == "all" else [args.shape]
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, out_dir, force=args.force)
                mesh_name = rec["mesh"]
                if rec["status"] == "ok":
                    mem_gb = (rec["memory"]["argument_bytes"]
                              + rec["memory"]["temp_bytes"]) / 2**30
                    print(f"[OK]   {arch:18s} {shape:12s} {mesh_name:10s} "
                          f"compile={rec.get('compile_s', 0):7.1f}s "
                          f"mem/dev={mem_gb:6.2f}GiB "
                          f"GFLOP/dev={rec['cost']['flops'] / 1e9:9.1f} "
                          f"coll={rec['collectives']['total_bytes'] / 2**20:8.1f}MiB",
                          flush=True)
                else:
                    print(f"[FAIL] {arch:18s} {shape:12s} {mesh_name:10s} "
                          f"{rec['error'][:160]}", flush=True)


if __name__ == "__main__":
    main()
