"""Production meshes. v5e pod = 16x16 = 256 chips; multi-pod = 2 pods.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax

from repro.sharding.rules import MeshRules, RULES_2D, RULES_3D


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def rules_for(mesh) -> MeshRules:
    import dataclasses
    base = RULES_3D if "pod" in mesh.axis_names else RULES_2D
    return dataclasses.replace(base, mesh=mesh)


def make_host_mesh(n: int | None = None, axes=("data",)):
    """Small CPU mesh for tests/examples (host platform devices)."""
    import numpy as np
    devs = jax.devices()
    n = n or len(devs)
    per = n // len(axes) if len(axes) > 1 else n
    shape = tuple([per] * len(axes)) if len(axes) > 1 else (n,)
    return jax.sharding.Mesh(np.array(devs[:int(np.prod(shape))]).reshape(shape), axes)


def make_search_mesh(spec: str | None = None, axes=("pop",)):
    """The one search-mesh constructor behind every `--mesh` knob
    (DESIGN.md §13) — engine, sweep and islands all route through here.

    ``spec`` grammar (device counts, innermost axis last):
      - None / "" / "none" -> None: the single-device oracle path;
      - "auto"             -> all host devices on the LAST axis (leading
                              axes get extent 1);
      - "4"                -> 4 devices on the last axis;
      - "2x4"              -> one extent per axis (len must match ``axes``).

    ``axes`` names the mesh axes: ("pop",) for a single sharded search,
    ("bucket", "pop") for the sweep's 2-D problems x population layout,
    ("data",) for the islands ring.
    """
    if spec is None or spec in ("", "none"):
        return None
    import numpy as np
    devs = jax.devices()
    if spec == "auto":
        shape = (1,) * (len(axes) - 1) + (len(devs),)
    else:
        try:
            dims = tuple(int(s) for s in spec.lower().split("x"))
        except ValueError:
            raise ValueError(
                f"bad mesh spec {spec!r}: want 'auto', 'N' or 'KxN'")
        if any(d < 1 for d in dims):
            raise ValueError(f"bad mesh spec {spec!r}: extents must be >= 1")
        if len(dims) == 1 and len(axes) > 1:
            dims = (1,) * (len(axes) - 1) + dims
        if len(dims) != len(axes):
            raise ValueError(
                f"mesh spec {spec!r} has {len(dims)} extents for "
                f"{len(axes)} axes {axes}")
        shape = dims
    n = int(np.prod(shape))
    if n > len(devs):
        raise ValueError(
            f"mesh spec {spec!r} needs {n} devices, host has {len(devs)} "
            f"(simulate more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.sharding.Mesh(np.array(devs[:n]).reshape(shape), axes)
