"""Production meshes. v5e pod = 16x16 = 256 chips; multi-pod = 2 pods.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax

from repro.sharding.rules import MeshRules, RULES_2D, RULES_3D


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def rules_for(mesh) -> MeshRules:
    import dataclasses
    base = RULES_3D if "pod" in mesh.axis_names else RULES_2D
    return dataclasses.replace(base, mesh=mesh)


def make_host_mesh(n: int | None = None, axes=("data",)):
    """Small CPU mesh for tests/examples (host platform devices)."""
    import numpy as np
    devs = jax.devices()
    n = n or len(devs)
    per = n // len(axes) if len(axes) > 1 else n
    shape = tuple([per] * len(axes)) if len(axes) > 1 else (n,)
    return jax.sharding.Mesh(np.array(devs[:int(np.prod(shape))]).reshape(shape), axes)
