"""Loop-aware HLO cost analysis (roofline source of truth).

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE — with scanned
layer stacks that under-counts FLOPs/bytes/collectives by ~n_layers (verified
in EXPERIMENTS.md §Roofline). This module parses `compiled.as_text()`
structurally instead:

  - computations + their call graph (while body/condition, calls=, fusions),
  - while trip counts recovered from the loop-condition constant,
  - per-computation: dot FLOPs (2 * |result| * K from inline operand shapes),
    collective payload bytes by kind, and op result bytes (memory-traffic
    proxy),
  - totals = sum over the call tree with trip-count multipliers composed.

Everything comes from the compiled artifact — no model-knowledge shortcuts —
so remat recompute, dispatch overheads and GSPMD-inserted collectives are all
included at their true per-step multiplicity.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
_CALLEE_RE = re.compile(
    r"(?:body|condition|calls|to_apply)=(?:%([\w.\-]+)|\(([^)]*)\))")
_DOT_RE = re.compile(r"=\s*([a-z]\d*[a-z0-9]*)\[([\d,]*)\][^=]*\bdot\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dt: str, dims: str) -> int:
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes_touched: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    callees: list = dataclasses.field(default_factory=list)  # (name, kind)
    max_const: int = 0  # for trip-count recovery when used as a condition
    shapes: dict = dataclasses.field(default_factory=dict)   # %name -> dims
    dots: list = dataclasses.field(default_factory=list)     # deferred
    const_vals: dict = dataclasses.field(default_factory=dict)
    compare_ops: list = dataclasses.field(default_factory=list)


def parse_computations(hlo_text: str):
    comps: dict[str, CompStats] = {}
    entries: list[str] = []
    cur: CompStats | None = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _COMP_HDR.match(line)
        if m and ("{" in line):
            cur = CompStats()
            comps[m.group(1)] = cur
            if line.startswith("ENTRY"):
                entries.append(m.group(1))
            continue
        if cur is None or not line or line == "}":
            continue

        # call edges — while ops pair their own (condition, body)
        if re.search(r"\swhile\(", line):
            cond_m = re.search(r"condition=%?([\w.\-]+)", line)
            body_m = re.search(r"body=%?([\w.\-]+)", line)
            if cond_m and body_m:
                cur.callees.append((body_m.group(1), "while_body:"
                                    + cond_m.group(1)))
                cur.callees.append((cond_m.group(1), "condition"))
        else:
            for cm in _CALLEE_RE.finditer(line):
                if cm.group(1):
                    names = [cm.group(1)]
                else:
                    names = [n.strip().lstrip("%")
                             for n in cm.group(2).split(",")]
                kind = cm.group(0).split("=")[0]
                for n in names:
                    if n:
                        cur.callees.append((n, kind))

        # integer constants (trip-count recovery for loop conditions):
        # record named constants; the compare op of a loop condition tells
        # us which one is the bound.
        if " constant(" in line:
            cm0 = _CONST_RE.search(line)
            if cm0:
                nm = line.split("=", 1)[0].strip().lstrip("%").split(" ")[0]
                cur.const_vals[nm] = int(cm0.group(1))
                cur.max_const = max(cur.max_const, int(cm0.group(1)))
        if " compare(" in line and "direction=LT" in line:
            ops = line.split("compare(", 1)[1].split(")")[0]
            for op in ops.split(","):
                cur.compare_ops.append(op.strip().lstrip("%"))

        if "=" not in line:
            continue
        lhs_name = line.split("=", 1)[0].strip().lstrip("%").split(" ")[0]
        rhs = line.split("=", 1)[1]

        # record the op's result shape (symbol table for dot operands)
        first = _SHAPE_RE.search(rhs)
        if first:
            cur.shapes[lhs_name] = [int(d) for d in first.group(2).split(",")
                                    if d]
            # HBM-traffic proxy: materialization-scale results only (>=1MiB);
            # small scanned ops live in registers/cache and would swamp the
            # estimate at 100s of loop trips
            b = _shape_bytes(first.group(1), first.group(2))
            if b >= (1 << 20):
                cur.bytes_touched += b

        # dot FLOPs deferred: 2 * |result| * K, K = prod(lhs contracting dims)
        if " dot(" in rhs:
            res = first.group(2) if first else ""
            inside = rhs.split("dot(", 1)[1]
            lhs_op = inside.split(",")[0].strip().lstrip("%")
            cm2 = _CONTRACT_RE.search(rhs)
            contract = [int(i) for i in cm2.group(1).split(",")
                        if i != ""] if cm2 else []
            cur.dots.append((res, lhs_op, contract))

        # collectives: result-shape payload per kind
        for kind in COLLECTIVES:
            if re.search(rf"\s{kind}(-start)?\(", rhs):
                lhs_types = rhs[: re.search(rf"\s{kind}(-start)?\(", rhs).start()]
                b = sum(_shape_bytes(dt, dims)
                        for dt, dims in _SHAPE_RE.findall(lhs_types))
                cur.coll_bytes[kind] = cur.coll_bytes.get(kind, 0) + b
                cur.coll_counts[kind] = cur.coll_counts.get(kind, 0) + 1
                break

    # resolve deferred dot FLOPs against each computation's symbol table
    for c in comps.values():
        for res_dims, lhs_op, contract in c.dots:
            res_elems = _shape_elems(res_dims)
            k = 1
            lhs_dims = c.shapes.get(lhs_op)
            if lhs_dims:
                for idx in contract:
                    if idx < len(lhs_dims):
                        k *= lhs_dims[idx]
            c.flops += 2.0 * res_elems * k
    return comps, entries


def analyze(hlo_text: str, entry: str | None = None) -> dict:
    """Walk the call tree from ENTRY with while-trip multipliers composed."""
    comps, entries = parse_computations(hlo_text)
    if entry is None:
        if entries:
            entry = entries[0]
        else:  # fall back: an uncalled computation (pick the biggest)
            called = {n for c in comps.values() for (n, _) in c.callees}
            roots = [n for n in comps if n not in called] or list(comps)
            entry = max(roots, key=lambda n: len(comps[n].shapes))

    totals = {"flops": 0.0, "bytes": 0.0,
              "collectives": {k: {"bytes": 0.0, "count": 0.0}
                              for k in COLLECTIVES}}

    import functools

    @functools.lru_cache(maxsize=None)
    def body_of_while_trip(cond_name: str) -> int:
        cond = comps.get(cond_name)
        if cond is None:
            return 1
        # precise: the compare(LT) operand that is a constant IS the bound
        for op in cond.compare_ops:
            if op in cond.const_vals:
                return max(1, cond.const_vals[op])
        return max(1, cond.max_const)

    visiting = set()

    def walk(name: str, mult: float, count_bytes: bool):
        if name not in comps or name in visiting:
            return
        visiting.add(name)
        c = comps[name]
        totals["flops"] += c.flops * mult
        if count_bytes:
            # fusion internals stay in registers/VMEM: their call-site result
            # is already counted in the parent — don't double count.
            totals["bytes"] += c.bytes_touched * mult
        for kind, b in c.coll_bytes.items():
            totals["collectives"][kind]["bytes"] += b * mult
            totals["collectives"][kind]["count"] += c.coll_counts[kind] * mult
        for (n, kind) in c.callees:
            if kind.startswith("while_body:"):
                trip = body_of_while_trip(kind.split(":", 1)[1])
                walk(n, mult * trip, count_bytes)
            elif kind in ("calls", "to_apply"):
                walk(n, mult, False)
            else:
                walk(n, mult, count_bytes)
        visiting.discard(name)

    walk(entry, 1.0, True)
    totals["collectives"]["total_bytes"] = sum(
        v["bytes"] for k, v in totals["collectives"].items()
        if isinstance(v, dict))
    return totals
