"""Production training launcher: mesh + sharded state + checkpointed loop.

On real hardware this is the per-process entry point (jax.distributed
initializes from the TPU environment); on this container it drives reduced
configs end-to-end with the same code path (see examples/lm_train.py for a
guided version with crash/resume).

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
        --reduced --steps 20 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.data import SyntheticLMData
from repro.launch.mesh import make_host_mesh, make_production_mesh, rules_for
from repro.models import transformer
from repro.optim import get_optimizer, warmup_cosine_schedule
from repro.runtime import checkpoint, train
from repro.sharding import params as sp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs 256 devices)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if args.production_mesh:
        mesh = make_production_mesh()
        rules = rules_for(mesh)
    else:
        mesh = make_host_mesh()
        rules = None

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    opt = get_optimizer(cfg, schedule=warmup_cosine_schedule(
        1e-3, 10, args.steps))
    if rules is not None:
        pspecs = sp.param_specs(cfg, rules, mesh)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, pspecs)
    state = train.init_train_state(params, opt)
    step_fn = jax.jit(train.make_train_step(cfg, rules=rules, optimizer=opt))

    start = 0
    if args.ckpt_dir and (last := checkpoint.latest_step(args.ckpt_dir)) is not None:
        state, start = checkpoint.restore(args.ckpt_dir, last, state)
        print(f"resumed from checkpoint step {start}")

    n_text = args.seq - cfg.prefix_len
    data = SyntheticLMData(cfg.vocab_size, n_text, args.batch, seed=0)
    t0 = time.time()
    with mesh:
        for step in range(start, args.steps):
            batch = {"tokens": jnp.asarray(data.batch(step)["tokens"])}
            if cfg.prefix_len:
                batch["prefix_embed"] = jnp.zeros(
                    (args.batch, cfg.prefix_len, cfg.d_model), jnp.float32)
            state, metrics = step_fn(state, batch)
            if args.ckpt_dir and step % args.ckpt_every == 0:
                checkpoint.save(args.ckpt_dir, step, state)
            if step % 5 == 0:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"({(time.time() - t0) / max(step - start + 1, 1):.2f} s/step)",
                      flush=True)
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps - 1, state)
    print(f"done: {args.steps - start} steps, "
          f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
