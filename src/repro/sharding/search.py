"""Partition specs for the mesh-sharded NSGA-II search state (DESIGN.md §13).

One place owns how an `nsga2.NSGA2State` lays out over the search mesh, so
the shard_map bodies in `core.dist`, the engine's checkpoint restore and the
tests all agree:

  - population arrays (genes/objs/rank/crowd) shard their population axis
    over the ``pop`` mesh axis;
  - the PRNG key and generation counter are REPLICATED — every shard draws
    identical randomness, which is what makes the sharded step's selection /
    variation bookkeeping bit-identical to the single-device oracle
    (`core.dist._sharded_gen_body`);
  - the batched (sweep) variants add a leading problem axis sharded over the
    ``bucket`` mesh axis; per-problem keys and generation counters follow
    the problem axis.
"""
from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import nsga2


def search_state_specs(axis: str = "pop") -> nsga2.NSGA2State:
    """PartitionSpec pytree for one sharded search state (shard_map specs)."""
    return nsga2.NSGA2State(genes=P(axis), objs=P(axis), rank=P(axis),
                            crowd=P(axis), key=P(), generation=P())


def batched_state_specs(bucket_axis: str = "bucket",
                        axis: str = "pop") -> nsga2.NSGA2State:
    """PartitionSpec pytree for a (problems, population) stacked state."""
    return nsga2.NSGA2State(
        genes=P(bucket_axis, axis), objs=P(bucket_axis, axis),
        rank=P(bucket_axis, axis), crowd=P(bucket_axis, axis),
        key=P(bucket_axis), generation=P(bucket_axis),
    )


def search_state_sharding(mesh: Mesh, axis: str = "pop") -> nsga2.NSGA2State:
    """NamedSharding pytree for device_put / elastic checkpoint restore."""
    spec = search_state_specs(axis)
    return nsga2.NSGA2State(
        **{f: NamedSharding(mesh, getattr(spec, f))
           for f in ("genes", "objs", "rank", "crowd", "key", "generation")})


def batched_state_sharding(mesh: Mesh, bucket_axis: str = "bucket",
                           axis: str = "pop") -> nsga2.NSGA2State:
    """NamedSharding pytree for the sweep's stacked sharded states."""
    spec = batched_state_specs(bucket_axis, axis)
    return nsga2.NSGA2State(
        **{f: NamedSharding(mesh, getattr(spec, f))
           for f in ("genes", "objs", "rank", "crowd", "key", "generation")})
