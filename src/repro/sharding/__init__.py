from repro.sharding.rules import MeshRules, maybe_shard, RULES_1D, RULES_2D, RULES_3D

__all__ = ["MeshRules", "maybe_shard", "RULES_1D", "RULES_2D", "RULES_3D"]
