"""Logical -> mesh sharding rules (DP / TP / SP / EP / pod).

One `MeshRules` instance fixes how every logical tensor axis maps onto mesh
axes. The production meshes (launch.mesh) are:

  single-pod: (data=16, model=16)            RULES_2D
  multi-pod:  (pod=2, data=16, model=16)     RULES_3D

Logical axes:
  batch    -> all data-parallel axes (pod + data)
  model    -> tensor-parallel axis (heads / d_ff / vocab shards)
  expert   -> axes carrying the MoE expert dim (kimi: data; grok: none)
  ff_wide  -> extra axes for very wide expert d_ff (grok: data+model)
  seq      -> sequence-parallel axis for saved residuals (Megatron SP)

`maybe_shard` is a no-op when rules is None (smoke tests on 1 CPU device)."""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshRules:
    tp: int                                  # size of the model axis
    batch: tuple[str, ...] = ("data",)
    model: str | None = "model"
    expert: tuple[str, ...] = ("data",)      # EP all-to-all dispatch axis
    ff_wide: tuple[str, ...] = ("data", "model")
    seq: str | None = "model"
    mesh: object = None                      # concrete Mesh for shard_map EP

    def batch_spec(self) -> tuple:
        return self.batch if self.batch else None


RULES_1D = None  # single-device smoke tests: no constraints

RULES_2D = MeshRules(tp=16, batch=("data",))

# experts dispatch across pods too (a2a over pod x data = 32-way): halves the
# per-device expert residency vs pod-replicated experts; grads for expert
# weights then never cross pods at all (fully sharded).
RULES_3D = MeshRules(tp=16, batch=("pod", "data"),
                     expert=("pod", "data"),
                     ff_wide=("pod", "data", "model"))


def maybe_shard(x, spec_entries, rules: MeshRules | None):
    """with_sharding_constraint if rules are active, identity otherwise.

    spec_entries: tuple of logical entries, each None | str | tuple resolved
    already to mesh-axis names (callers use rules.* fields).
    """
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec_entries))


def head_sharding(cfg, rules: MeshRules | None):
    """Resolve the attention head-sharding mode for this (arch, mesh).

    Returns (mode, kv_repeat):
      mode "sharded":   n_heads % tp == 0 — heads over the model axis; KV
                        heads repeated by kv_repeat so they divide tp too.
      mode "replicated": heads indivisible (paligemma/gemma 8H, llama 24H) —
                        attention weights replicated over model axis.
    """
    if rules is None or cfg.n_heads == 0:
        return "replicated", 1
    tp = rules.tp
    if cfg.n_heads % tp == 0:
        group = cfg.n_heads // cfg.n_kv_heads
        r = 1
        while (cfg.n_kv_heads * r) % tp != 0 and r < group:
            r *= 2
        if (cfg.n_kv_heads * r) % tp == 0 and group % r == 0:
            return "sharded", r
    return "replicated", 1
