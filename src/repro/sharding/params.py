"""Parameter / optimizer-state / cache PartitionSpec trees.

Specs are derived from the param pytree structure (path + shape) under a
MeshRules instance, with divisibility checks everywhere: a dim is sharded
over a mesh-axis group only when its size divides the group size — otherwise
it falls back to replication (recorded hillclimb levers in DESIGN.md §5).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import PartitionSpec as P

from repro.models import transformer
from repro.runtime.train import TrainState
from repro.sharding.rules import MeshRules, head_sharding


def _axes_size(mesh_shape: dict, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh_shape[axes]
    return int(np.prod([mesh_shape[a] for a in axes]))


def _axis_if(size: int, axes, mesh_shape):
    if axes is None:
        return None
    n = _axes_size(mesh_shape, axes)
    if n > 1 and size % n == 0:
        return axes if isinstance(axes, str) else tuple(axes)
    return None


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_specs(cfg, rules: MeshRules | None, mesh):
    """PartitionSpec pytree matching transformer.init_params(cfg)."""
    shapes = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg), jax.random.PRNGKey(0))
    if rules is None:
        return jax.tree.map(lambda _: P(), shapes)
    ms = dict(mesh.shape)
    mode, kv_repeat = head_sharding(cfg, rules)
    mdl = rules.model

    # expert-dim / expert-ff axes — must mirror models.moe._ep_mode
    if cfg.n_experts:
        from repro.models.moe import _ep_mode
        import dataclasses as _dc
        ep_mode = _ep_mode(cfg, _dc.replace(rules, mesh=mesh))
        if ep_mode == "alltoall":
            ex_ax, eff_ax = rules.expert, rules.model
        else:
            ex_ax, eff_ax = None, rules.ff_wide
    else:
        ex_ax, eff_ax = None, None

    def spec_for(path, leaf):
        name = _path_str(path)
        shp = leaf.shape
        last = name.rsplit("/", 1)[-1]

        def build(base_ndim, entries):
            lead = len(shp) - base_ndim
            return P(*([None] * lead + list(entries)))

        if last in ("embed", "lm_head"):
            return P(_axis_if(shp[0], mdl, ms), None)
        # weights that cannot shard over the model axis fall back to
        # ZeRO-3-style sharding of the d_model dim over 'data' (gathered on
        # use — a few MB per layer — instead of replicated residency).
        zero_ax = rules.batch[-1] if rules.batch else None
        if "attn" in name:
            if last == "wq":
                ax = _axis_if(shp[-2], mdl, ms) if mode == "sharded" else None
                d_ax = None if ax else _axis_if(shp[-3], zero_ax, ms)
                return build(3, [d_ax, ax, None])
            if last in ("wk", "wv"):
                ax = _axis_if(shp[-2], mdl, ms) if mode == "sharded" else None
                d_ax = None if ax else _axis_if(shp[-3], zero_ax, ms)
                return build(3, [d_ax, ax, None])
            if last == "wo":
                ax = _axis_if(shp[-3], mdl, ms) if mode == "sharded" else None
                d_ax = None if ax else _axis_if(shp[-1], zero_ax, ms)
                return build(3, [ax, None, d_ax])
        if "ffn" in name and cfg.n_experts:
            if last in ("wi", "wg"):
                return build(3, [_axis_if(shp[-3], ex_ax, ms), None,
                                 _axis_if(shp[-1], eff_ax, ms)])
            if last == "wo":
                return build(3, [_axis_if(shp[-3], ex_ax, ms),
                                 _axis_if(shp[-2], eff_ax, ms), None])
            if last == "router":
                return build(2, [_axis_if(shp[-2], zero_ax, ms), None])
        if "ffn" in name:
            if last in ("wi", "wg"):
                return build(2, [None, _axis_if(shp[-1], mdl, ms)])
            if last == "wo":
                return build(2, [_axis_if(shp[-2], mdl, ms), None])
        if "ssm" in name:
            if last in ("z_proj", "x_proj", "dt_proj"):
                return build(2, [None, _axis_if(shp[-1], mdl, ms)])
            if last == "conv_x_w":
                return build(2, [None, _axis_if(shp[-1], mdl, ms)])
            if last == "out_proj":
                return build(2, [_axis_if(shp[-2], mdl, ms), None])
        return P(*([None] * len(shp)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def opt_state_specs(cfg, rules, mesh, optimizer_name: str):
    """Optimizer-state specs: param specs + ZeRO-1.

    Adam moments additionally shard their largest still-unsharded dim over
    the data axis (ZeRO-1): GSPMD turns the update into reduce-scatter(g) ->
    sharded moment update -> all-gather(delta), so f32 moments never cost
    more than params_bytes/|data| per device.
    """
    pspecs = param_specs(cfg, rules, mesh)
    shapes = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg), jax.random.PRNGKey(0))
    ms = dict(mesh.shape) if rules is not None else {}
    zero_ax = rules.batch[-1] if (rules and rules.batch) else None

    def zero1(spec, leaf):
        if rules is None or zero_ax is None:
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a:
                    used.add(a)
        if zero_ax in used:
            return spec
        order = sorted(range(len(entries)),
                       key=lambda i: -leaf.shape[i])
        for i in order:
            if entries[i] is None and leaf.shape[i] % ms[zero_ax] == 0 \
                    and leaf.shape[i] >= ms[zero_ax]:
                entries[i] = zero_ax
                return P(*entries)
        return spec

    z1specs = jax.tree.map(zero1, pspecs, shapes,
                           is_leaf=lambda x: isinstance(x, P))
    if optimizer_name == "adamw":
        return {"m": z1specs, "v": z1specs}

    # adafactor: vr drops the last dim's entry, vc the second-to-last's
    def factored(spec):
        entries = list(spec)
        if len(entries) >= 2:
            return {"vr": P(*entries[:-1]),
                    "vc": P(*entries[:-2] + entries[-1:])}
        return {"v": P(*entries)}

    return jax.tree.map(factored, pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def train_state_specs(cfg, rules, mesh, optimizer_name: str) -> TrainState:
    return TrainState(
        params=param_specs(cfg, rules, mesh),
        opt_state=opt_state_specs(cfg, rules, mesh, optimizer_name),
        step=P(),
    )


def batch_specs(cfg, rules, mesh, batch_dict):
    """Input batch specs: batch dim over the data axes when divisible."""
    if rules is None:
        return jax.tree.map(lambda _: P(), batch_dict)
    ms = dict(mesh.shape)

    def one(leaf):
        ax = _axis_if(leaf.shape[0], rules.batch, ms)
        return P(*([ax] + [None] * (len(leaf.shape) - 1)))

    return jax.tree.map(one, batch_dict)


def cache_specs(cfg, rules, mesh, caches):
    """Decode-cache specs: batch over data axes, heads over model."""
    if rules is None:
        return jax.tree.map(lambda _: P(), caches)
    ms = dict(mesh.shape)
    mode, _ = head_sharding(cfg, rules)

    def one(leaf):
        shp = leaf.shape
        if len(shp) == 5:   # kv cache (L, B, S_max, KV_true, hd)
            # context-parallel decode: cache sharded on the SEQUENCE dim —
            # works for any kv head count and never pays a repeat factor.
            seq_ax = _axis_if(shp[2], rules.model, ms)
            return P(None, _axis_if(shp[1], rules.batch, ms), seq_ax,
                     None, None)
        if len(shp) == 4:   # ssm conv (L, B, K-1, C)
            return P(None, _axis_if(shp[1], rules.batch, ms), None, None)
        return P(*([None] * len(shp)))

    def route(leaf):
        shp = leaf.shape
        if len(shp) == 5 and shp[-1] == cfg.ssm_state and cfg.ssm_state:
            # ssm state (L, B, NH, HD, N)
            return P(None, _axis_if(shp[1], rules.batch, ms),
                     _axis_if(shp[2], rules.model, ms), None, None)
        return one(leaf)

    return jax.tree.map(route, caches)
