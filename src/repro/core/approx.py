"""Dual approximation fitness: chromosome -> (accuracy loss, area).

One chromosome holds 2N genes (paper Fig. 3a): per comparator a precision
gene (decoded to p in [2,8]) and a margin gene (decoded to m in [-5,+5]).
Fitness is evaluated fully vectorized: the entire population is one batched
tensor program (vmap over chromosomes), which is this framework's TPU-native
replacement for the paper's thread-per-chromosome evaluation.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import area as area_mod
from repro.core import quant
from repro.core.tree import ParallelTree, leaves_from_decisions
from repro.datasets.synthetic import quantize_u8


@dataclasses.dataclass
class ApproxProblem:
    """Immutable evaluation context for one (tree, dataset) pair."""

    feature: jnp.ndarray     # (N,) int32
    threshold: jnp.ndarray   # (N,) float32
    path: jnp.ndarray        # (L, N) int8
    path_len: jnp.ndarray    # (L,) int32
    leaf_class: jnp.ndarray  # (L,) int32
    x8: jnp.ndarray          # (B, F) int32 master codes (test set)
    y: jnp.ndarray           # (B,) int32
    area_lut: jnp.ndarray    # flat LUT (mm^2)
    lut_offsets: jnp.ndarray  # (MAX_BITS+1,) int32
    overhead_mm2: float
    exact_area_mm2: float
    exact_accuracy: float
    n_classes: int

    @property
    def n_comparators(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_genes(self) -> int:
        return 2 * self.n_comparators


def _decode_thresholds(problem: ApproxProblem, genes):
    bits, margin = quant.decode_genes(genes)
    t_int = quant.threshold_to_int(problem.threshold, bits)
    t_sub = quant.substitute(t_int, margin, bits)
    return bits, t_sub


def chromosome_area_mm2(problem: ApproxProblem, genes):
    bits, t_sub = _decode_thresholds(problem, genes)
    idx = problem.lut_offsets[bits] + t_sub
    return problem.area_lut[idx].sum() + problem.overhead_mm2


def chromosome_accuracy(problem: ApproxProblem, genes):
    bits, t_sub = _decode_thresholds(problem, genes)
    x_gathered = problem.x8[:, problem.feature]              # (B, N)
    x_p = quant.inputs_at_precision(x_gathered, bits)
    decisions = x_p > t_sub[None, :]
    leaf = leaves_from_decisions(decisions, problem.path, problem.path_len)
    pred = problem.leaf_class[leaf]
    return jnp.mean((pred == problem.y).astype(jnp.float32))


def objectives(problem: ApproxProblem, genes):
    """(accuracy_loss vs exact, normalized area) — both minimized.

    Accuracy loss is relative to the exact bespoke design (paper's reference
    point for the 1%/2% thresholds); area normalized by the exact design's
    (paper Fig. 5 normalizes the same way).
    """
    acc = chromosome_accuracy(problem, genes)
    area = chromosome_area_mm2(problem, genes)
    return jnp.stack([problem.exact_accuracy - acc, area / problem.exact_area_mm2])


def make_fitness_fn(problem: ApproxProblem):
    """Population fitness: (P, 2N) genes -> (P, 2) objectives, jitted."""

    @jax.jit
    def fitness(pop):
        return jax.vmap(functools.partial(objectives, problem))(pop)

    return fitness


def make_fitness_fn_kernel(problem: ApproxProblem, ptree: ParallelTree,
                           n_features: int, interpret: bool | None = None):
    """Kernel-backed fitness: accuracy via the fused Pallas tree_infer kernel
    (population x batch grid), area via the LUT gather. Same objectives as
    make_fitness_fn — asserted equal in tests."""
    from repro.kernels import ops as kops  # local import: kernels are optional

    operands = kops.prepare_tree_operands(ptree, n_features)
    threshold = problem.threshold

    @jax.jit
    def fitness(pop):
        scale, thr = kops.decode_population(threshold, pop)
        preds = kops.tree_infer_predict(problem.x8, operands, scale, thr,
                                        interpret=interpret)
        acc = jnp.mean((preds == problem.y[None, :]).astype(jnp.float32), axis=1)
        bits, margin = quant.decode_genes(pop)
        t_int = quant.threshold_to_int(threshold[None, :], bits)
        t_sub = quant.substitute(t_int, margin, bits)
        areas = problem.area_lut[problem.lut_offsets[bits] + t_sub].sum(axis=1)
        areas = areas + problem.overhead_mm2
        return jnp.stack(
            [problem.exact_accuracy - acc, areas / problem.exact_area_mm2], axis=1
        )

    return fitness


def build_problem(ptree: ParallelTree, x_test: np.ndarray, y_test: np.ndarray) -> ApproxProblem:
    lut, offsets = area_mod.build_area_lut()
    x8 = quantize_u8(x_test).astype(np.int32)
    overhead = area_mod.tree_overhead_mm2(ptree.n_comparators, ptree.n_leaves)

    # exact design: 8-bit, zero margin
    exact_bits = np.full(ptree.n_comparators, quant.MAX_BITS, dtype=np.int64)
    t8 = np.clip(
        np.floor(ptree.threshold * 256.0).astype(np.int64), 0, 255
    )
    exact_area = float(lut[offsets[exact_bits] + t8].sum() + overhead)

    problem = ApproxProblem(
        feature=jnp.asarray(ptree.feature),
        threshold=jnp.asarray(ptree.threshold),
        path=jnp.asarray(ptree.path),
        path_len=jnp.asarray(ptree.path_len),
        leaf_class=jnp.asarray(ptree.leaf_class),
        x8=jnp.asarray(x8),
        y=jnp.asarray(y_test.astype(np.int32)),
        area_lut=jnp.asarray(lut),
        lut_offsets=jnp.asarray(offsets),
        overhead_mm2=float(overhead),
        exact_area_mm2=exact_area,
        exact_accuracy=0.0,  # filled below
        n_classes=ptree.n_classes,
    )
    exact_acc = float(
        chromosome_accuracy(problem, jnp.asarray(quant.exact_genes(ptree.n_comparators)))
    )
    return dataclasses.replace(problem, exact_accuracy=exact_acc)


jax.tree_util.register_pytree_node(
    ApproxProblem,
    lambda p: (
        (p.feature, p.threshold, p.path, p.path_len, p.leaf_class, p.x8, p.y,
         p.area_lut, p.lut_offsets),
        (p.overhead_mm2, p.exact_area_mm2, p.exact_accuracy, p.n_classes),
    ),
    lambda aux, children: ApproxProblem(*children, *aux),
)
