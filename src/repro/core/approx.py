"""Dual approximation fitness: chromosome -> (accuracy loss, area).

One chromosome holds 3N+1 genes (paper Fig. 3a plus DESIGN.md §16): per
comparator a precision gene (decoded to p in [2,8]), a margin gene (decoded
to m in [-5,+5]) and an LSB-truncation gene (k in [0,2]), plus one trailing
vote-adder gene (exact vs saturating-OR majority vote; inert for one tree).

This module is now a thin single-tree adapter over the unified search engine
in `repro.search` (DESIGN.md §7): `ApproxProblem` IS a
`repro.search.SearchProblem` with one tree, and the fitness factories
delegate to the engine's `reference` / `kernel` backends. New code should use
`repro.search` directly — `build_tree_problem` / `build_forest_problem` +
`run_search` — which adds forest chromosomes, the fused multi-tree Pallas
path, island parallelism, checkpointing and pareto artifacts.
"""
from __future__ import annotations

import numpy as np

from repro.core.tree import ParallelTree
from repro.search.problem import (
    SearchProblem,
    build_tree_problem,
    chromosome_accuracy,
    chromosome_area_mm2,
    objectives,
)
from repro.search.backends import make_kernel_fitness, make_reference_fitness

# Back-compat alias: the single-tree problem is the K=1 SearchProblem.
ApproxProblem = SearchProblem


def build_problem(ptree: ParallelTree, x_test: np.ndarray,
                  y_test: np.ndarray) -> SearchProblem:
    """Single-tree evaluation context (the K=1 `SearchProblem`)."""
    return build_tree_problem(ptree, x_test, y_test)


def make_fitness_fn(problem: SearchProblem):
    """Population fitness: (P, 3N+1) genes -> (P, 2) objectives, jitted.

    Adapter for `repro.search.make_reference_fitness` (pure-jnp backend).
    """
    return make_reference_fitness(problem)


def make_fitness_fn_kernel(problem: SearchProblem,
                           ptree: ParallelTree | None = None,
                           n_features: int | None = None,
                           interpret: bool | None = None):
    """Kernel-backed fitness via the fused Pallas tree_infer program.

    `ptree` / `n_features` are retained for signature compatibility; the
    problem object already carries the tree layout and feature count.
    """
    del ptree, n_features  # recoverable from the SearchProblem itself
    return make_kernel_fitness(problem, interpret=interpret)


__all__ = [
    "ApproxProblem",
    "build_problem",
    "chromosome_accuracy",
    "chromosome_area_mm2",
    "objectives",
    "make_fitness_fn",
    "make_fitness_fn_kernel",
]
