"""Gate-level stuck-at fault injection on the netlist IR (DESIGN.md §17).

Printed circuits are fabricated at yields where individual gates *will*
fail, and the question that decides whether a Pareto design is shippable is
not its defect-free accuracy but what accuracy survives when gates stick.
This module turns any `core.netlist.Circuit` into a fault-injection target:

  - `enumerate_fault_sites(circuit)` lists every injectable site — the
    output of each logic gate plus each primary-input bit (INPUT gates).
    Constants are not sites: a CONST gate *is* a stuck wire already.
  - `FaultSimulator` evaluates **fault-lanes x test-vectors in one batched
    program**: the single-lane evaluator mirrors `netlist.simulate`'s
    levelized schedule gate-for-gate (same `levelize`, same per-level
    gather/op expressions), then applies the lane's stuck-at overrides as a
    per-level mask (`where(stuck_mask[level_gates], stuck_val, computed)`),
    and `jax.vmap` lifts it over a whole chunk of fault lanes at once.
    A lane with an empty mask is therefore *bit-identical* to
    `netlist.simulate` — the zero-fault invariant `check_bench` pins at
    exactly 0 mismatches.
  - `simulate_faulty_serial` is the deliberately naive oracle: a pure
    Python/numpy loop over gates in topological order with the fault
    applied on the way. The vmapped campaign is pinned array-for-array
    against it in `tests/test_faults.py`.

Fault lanes are expressed as dense (G,) stuck masks + values, so one
simulator serves both campaign shapes: single stuck-at faults are one-hot
masks (`site_masks`), Monte-Carlo defect draws are multi-hot masks sampled
by `search.robustness` under fixed PRNG keys. Chunks are padded to a fixed
lane count so a campaign compiles at most one program per (chunk, batch)
shape regardless of how many sites a circuit has.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.netlist import (
    AND,
    CONST1,
    INPUT,
    NOT,
    OR,
    Circuit,
    levelize,
)

# fault-lane chunk sizing: lanes per dispatch are chosen so a chunk's
# boolean value tensor (chunk, B, G) stays under this budget
DEFAULT_CHUNK_BUDGET_BYTES = 64 << 20
MAX_CHUNK = 256


@dataclasses.dataclass(frozen=True)
class FaultSite:
    """One injectable stuck-at location.

    `gate` indexes the circuit's gate arrays; `kind` is "input" for a
    primary-input bit (op == INPUT, where `feature`/`bit` name the master
    code bit) and "gate" for a logic-gate output; `label` is the stable
    human-readable name used in fault reports."""

    gate: int
    kind: str       # "input" | "gate"
    op: str         # OP_NAMES entry ("input", "not", "and", "or", "xor")
    label: str
    feature: int = -1   # input sites only
    bit: int = -1       # input sites only


def enumerate_fault_sites(circuit: Circuit) -> list[FaultSite]:
    """Every injectable site: logic-gate outputs + primary-input bits.

    Sites are ordered by gate id (deterministic); constants are excluded —
    CONST0/CONST1 are stuck wires by definition, and the hash-consed
    builder guarantees they occupy gates 0 and 1.
    """
    from repro.core.netlist import OP_NAMES

    sites = []
    for g in range(circuit.n_gates):
        op = int(circuit.op[g])
        if op <= CONST1:
            continue
        if op == INPUT:
            f, b = int(circuit.a[g]), int(circuit.b[g])
            sites.append(FaultSite(g, "input", "input",
                                   f"input[f{f}.b{b}]", feature=f, bit=b))
        else:
            sites.append(FaultSite(g, "gate", OP_NAMES[op],
                                   f"{OP_NAMES[op]}@{g}"))
    return sites


def site_masks(n_gates: int, gates, values) -> tuple[np.ndarray, np.ndarray]:
    """One-hot (S, G) stuck mask/value pairs for single-fault lanes."""
    gates = np.asarray(gates, np.int64)
    values = np.asarray(values)
    if gates.shape != values.shape:
        raise ValueError(
            f"gates {gates.shape} and values {values.shape} differ")
    s = gates.shape[0]
    mask = np.zeros((s, n_gates), bool)
    val = np.zeros((s, n_gates), bool)
    mask[np.arange(s), gates] = True
    val[np.arange(s), gates] = values.astype(bool)
    return mask, val


def single_fault_lanes(circuit: Circuit, sites=None):
    """(gates (2S,), values (2S,)) covering stuck-at-0 AND stuck-at-1 of
    every site — fault lane 2k is site k stuck-at-0, lane 2k+1 stuck-at-1."""
    if sites is None:
        sites = enumerate_fault_sites(circuit)
    gates = np.repeat(np.asarray([s.gate for s in sites], np.int64), 2)
    values = np.tile(np.asarray([0, 1], np.int64), len(sites))
    return gates, values


def auto_chunk(circuit: Circuit, n_samples: int,
               budget_bytes: int = DEFAULT_CHUNK_BUDGET_BYTES) -> int:
    """Fault lanes per dispatch keeping the (chunk, B, G) bool tensor under
    `budget_bytes` (clamped to [1, MAX_CHUNK])."""
    per_lane = max(1, int(n_samples) * circuit.n_gates)
    return int(np.clip(budget_bytes // per_lane, 1, MAX_CHUNK))


class FaultSimulator:
    """Vmapped stuck-at simulator over one circuit's levelized schedule.

    The per-lane evaluator repeats `netlist.simulate`'s exact computation
    (same levels, same masked gathers, same boolean expressions) with one
    addition: after each level's outputs are computed — the base level
    included — the lane's stuck-at override is applied as a mask, so a
    stuck gate presents its stuck value to every consumer while its own
    operand evaluation is unchanged (the standard stuck-at model).
    """

    def __init__(self, circuit: Circuit):
        self.circuit = circuit
        level = levelize(circuit)
        logic = np.asarray(circuit.op) >= NOT
        self._base = np.flatnonzero(level == 0)
        self._levels = [np.flatnonzero(level == lvl)
                        for lvl in range(1, int(level.max()) + 1
                                         if logic.any() else 1)]
        self._vmapped = jax.jit(
            jax.vmap(self._sim_one, in_axes=(None, 0, 0)))

    # -- the single-lane evaluator (mirror of netlist.simulate) ------------
    def _sim_one(self, x8, stuck_mask, stuck_val):
        """(B, F) codes + (G,) stuck mask/value -> (B,) predicted class."""
        circuit = self.circuit
        op, a, b = circuit.op, circuit.a, circuit.b
        g = circuit.n_gates
        n_b = x8.shape[0]
        vals = jnp.zeros((n_b, g), jnp.bool_)

        base = self._base
        feat = np.maximum(a[base], 0)
        bit = np.maximum(b[base], 0)
        in_vals = ((x8[:, feat] >> bit[None, :]) & 1).astype(jnp.bool_)
        base_ops = op[base][None, :]
        base_vals = jnp.where(base_ops == INPUT, in_vals, base_ops == CONST1)
        base_vals = jnp.where(stuck_mask[base][None, :],
                              stuck_val[base][None, :], base_vals)
        vals = vals.at[:, base].set(base_vals)

        for idx in self._levels:
            if idx.size == 0:
                continue
            av = vals[:, a[idx]]
            bv = vals[:, np.maximum(b[idx], 0)]
            ops = op[idx][None, :]
            out = jnp.where(
                ops == NOT, ~av,
                jnp.where(ops == AND, av & bv,
                          jnp.where(ops == OR, av | bv, av ^ bv)))
            out = jnp.where(stuck_mask[idx][None, :],
                            stuck_val[idx][None, :], out)
            vals = vals.at[:, idx].set(out)

        cls = jnp.zeros((n_b,), jnp.int32)
        for i, w in enumerate(circuit.out_bits):
            cls = cls | (vals[:, w].astype(jnp.int32) << i)
        return cls

    # -- batched campaigns -------------------------------------------------
    def run_masks(self, x8, stuck_mask, stuck_val,
                  chunk: int | None = None) -> np.ndarray:
        """(S, G) stuck masks/values -> (S, B) predictions.

        Lanes run in chunks of `chunk` (auto-sized to the memory budget by
        default); the final chunk pads with zero-fault lanes and crops, so
        at most one program compiles per (chunk, batch) shape.
        """
        x8 = jnp.asarray(x8, jnp.int32)
        stuck_mask = np.asarray(stuck_mask, bool)
        stuck_val = np.asarray(stuck_val, bool)
        if stuck_mask.ndim != 2 or stuck_mask.shape[1] != self.circuit.n_gates:
            raise ValueError(
                f"stuck masks must be (S, {self.circuit.n_gates}), got "
                f"{stuck_mask.shape}")
        if stuck_val.shape != stuck_mask.shape:
            raise ValueError(
                f"stuck values {stuck_val.shape} do not match masks "
                f"{stuck_mask.shape}")
        s = stuck_mask.shape[0]
        if chunk is None:
            chunk = auto_chunk(self.circuit, int(x8.shape[0]))
        chunk = max(1, min(int(chunk), max(s, 1)))
        out = []
        for lo in range(0, s, chunk):
            m = stuck_mask[lo:lo + chunk]
            v = stuck_val[lo:lo + chunk]
            pad = chunk - m.shape[0]
            if pad:
                m = np.pad(m, ((0, pad), (0, 0)))
                v = np.pad(v, ((0, pad), (0, 0)))
            preds = self._vmapped(x8, jnp.asarray(m), jnp.asarray(v))
            out.append(np.asarray(preds[:chunk - pad if pad else chunk]))
        if not out:
            return np.zeros((0, int(x8.shape[0])), np.int32)
        return np.concatenate(out, axis=0)

    def run_sites(self, x8, gates, values,
                  chunk: int | None = None) -> np.ndarray:
        """Single-fault lanes: (S,) site gates + stuck values -> (S, B)."""
        mask, val = site_masks(self.circuit.n_gates, gates, values)
        return self.run_masks(x8, mask, val, chunk=chunk)

    def run_zero_fault(self, x8) -> np.ndarray:
        """(B,) predictions of the defect-free lane — must be bit-identical
        to `netlist.simulate` (the mask is empty, so the levelized programs
        compute the same booleans in the same order)."""
        g = self.circuit.n_gates
        empty = np.zeros((1, g), bool)
        return self.run_masks(x8, empty, empty, chunk=1)[0]


def simulate_faulty_serial(circuit: Circuit, x8, faults=()) -> np.ndarray:
    """Serial per-gate oracle: (B,) predictions under `faults`.

    `faults` is an iterable of (gate, stuck_value) pairs. Evaluates gates
    one at a time in topological order with plain numpy — the reference the
    vmapped `FaultSimulator` is pinned against, sharing no jnp code with it.
    """
    x8 = np.asarray(x8, np.int64)
    n_b = x8.shape[0]
    op, a, b = circuit.op, circuit.a, circuit.b
    stuck = {int(g): bool(v) for g, v in faults}
    vals = np.zeros((circuit.n_gates, n_b), bool)
    for g in range(circuit.n_gates):
        o = int(op[g])
        if o == CONST1:
            v = np.ones(n_b, bool)
        elif o == INPUT:
            v = ((x8[:, int(a[g])] >> int(b[g])) & 1).astype(bool)
        elif o == NOT:
            v = ~vals[int(a[g])]
        elif o == AND:
            v = vals[int(a[g])] & vals[int(b[g])]
        elif o == OR:
            v = vals[int(a[g])] | vals[int(b[g])]
        elif o == NOT + 3:  # XOR (opcode 6)
            v = vals[int(a[g])] ^ vals[int(b[g])]
        else:               # CONST0
            v = np.zeros(n_b, bool)
        if g in stuck:
            v = np.full(n_b, stuck[g])
        vals[g] = v
    cls = np.zeros(n_b, np.int32)
    for i, w in enumerate(circuit.out_bits):
        cls |= vals[w].astype(np.int32) << i
    return cls
