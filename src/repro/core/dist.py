"""Distributed NSGA-II: population sharding + island model across the mesh.

Three levels, matching DESIGN.md §6 and §13:

1. `sharded_fitness` — data-parallel fitness: the population tensor is sharded
   over mesh axes; each device evaluates its slice against the (replicated)
   dataset. The GA bookkeeping (P×P domination, selection) happens on the
   gathered objectives — tiny (P×2).

2. `make_sharded_chunk` / `make_sharded_batched_chunk` — ONE global NSGA-II
   population with its axis sharded over the mesh (DESIGN.md §13). Fitness —
   the dominant cost — runs on per-shard population slabs (the fused fitness
   kernel unmodified per shard), and the O(P²) domination relation is
   *hierarchical*: each shard computes only its (P/S, P) row block against
   the all-gathered objectives, then the front-peel merges per-shard
   dominator-count partials with `psum`s — O(P) integer vectors on the wire
   per peel, never the O(P²) matrix. Integer sums partition exactly over
   shards, and every remaining reduction is replicated bookkeeping on tiny
   (P, 2) gathers, so the sharded search is bit-identical to the
   single-device `nsga2.make_chunk` oracle (tests pin array-for-array
   equality). The batched variant vmaps the same generation body over a
   second mesh axis of sweep buckets, spreading the 10-dataset campaign over
   a 2-D mesh.

3. `island_step` / `run_islands` — one NSGA-II *island* per mesh group (pods
   at production scale). Islands evolve independently (zero cross-pod traffic
   in the inner loop) and exchange elites via a `ppermute` ring every
   `migrate_every` generations. A dead pod costs search breadth, not
   correctness — the fault-tolerance story for the GA workload.

Rounds are device-resident: the chunk makers scan whole checkpoint intervals
in one dispatch (DESIGN.md §9), and `island_state_sharding` /
`sharded_state_sharding` give the sharding pytrees
`runtime.checkpoint.restore` needs to re-shard a saved state onto the
current mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import nsga2


def sharded_fitness(fitness_fn, mesh: Mesh, axis: str = "data"):
    """Wrap a (P, G) -> (P, M) fitness so the population axis is sharded."""
    pspec = P(axis)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(pspec,),
        out_specs=pspec,
        check_rep=False,
    )
    def _eval(genes):
        return fitness_fn(genes)

    @jax.jit
    def eval_sharded(genes):
        return _eval(genes)

    return eval_sharded


# ---------------------------------------------------------------------------
# Mesh-sharded global NSGA-II (DESIGN.md §13)
# ---------------------------------------------------------------------------

def _hierarchical_ranks(objs_local, objs_full, axis: str):
    """Global NSGA-II ranks from a per-shard row block (inside shard_map).

    ``objs_local`` (P_local, M) is this shard's contiguous slab of the
    ``objs_full`` (P, M) pool — slab i covers rows [i*P_local, (i+1)*P_local)
    in mesh-axis order (what a tiled all_gather produces). Each shard
    computes only its rows of the domination relation — O(P²/S) pairwise
    work, routed through `nsga2._dispatch_domination` on the LOCAL row count
    (the §13 routing fix) — and the shared front-peel merges the per-shard
    dominator-count partials with `psum`s. Integer sums partition exactly
    over shards, so the (replicated) result equals the monolithic sort's
    bit-for-bit.
    """
    p_local = objs_local.shape[0]
    start = jax.lax.axis_index(axis) * p_local
    dom_rows = nsga2._dispatch_domination(objs_local, objs_full)
    n_dominators = jax.lax.psum(
        dom_rows.sum(axis=0).astype(jnp.int32), axis)

    def dec(current):
        cur_rows = jax.lax.dynamic_slice_in_dim(current, start, p_local)
        part = (dom_rows & cur_rows[:, None]).sum(axis=0).astype(jnp.int32)
        return jax.lax.psum(part, axis)

    return nsga2._peel_fronts(n_dominators, dec)


def sharded_non_dominated_sort(objs, mesh: Mesh, axis: str = "pop"):
    """`nsga2.non_dominated_sort` with the population axis sharded over
    ``axis``: per-shard (P/S, P) domination rows merged hierarchically.

    ``objs`` (P, M) with P divisible by the mesh axis size. Returns the (P,)
    global ranks (sharded like the input), bit-identical to the monolithic
    sort."""
    _check_divisible(objs.shape[0], mesh, axis, "population")

    @partial(shard_map, mesh=mesh, in_specs=(P(axis),), out_specs=P(axis),
             check_rep=False)
    def _sort(objs_local):
        full = jax.lax.all_gather(objs_local, axis, tiled=True)
        ranks = _hierarchical_ranks(objs_local, full, axis)
        start = jax.lax.axis_index(axis) * objs_local.shape[0]
        return jax.lax.dynamic_slice_in_dim(ranks, start,
                                            objs_local.shape[0])

    return jax.jit(_sort)(objs)


def sharded_crowding_distance(objs, rank, mesh: Mesh, axis: str = "pop"):
    """`nsga2.crowding_distance` over a sharded population.

    Crowding is global — every distance depends on the whole front's sort
    order — and its f32 per-axis contributions are added SEQUENTIALLY in
    axis order; psum-merging per-shard partial sums would reassociate those
    adds and drift by an ulp per generation (DESIGN.md §13). So each shard
    gathers the (tiny, (P, M)) objectives and replicates the exact oracle
    arithmetic, returning its slab of the identical result."""
    _check_divisible(objs.shape[0], mesh, axis, "population")

    @partial(shard_map, mesh=mesh, in_specs=(P(axis), P(axis)),
             out_specs=P(axis), check_rep=False)
    def _crowd(objs_local, rank_local):
        full = jax.lax.all_gather(objs_local, axis, tiled=True)
        rank_full = jax.lax.all_gather(rank_local, axis, tiled=True)
        crowd = nsga2.crowding_distance(full, rank_full)
        start = jax.lax.axis_index(axis) * objs_local.shape[0]
        return jax.lax.dynamic_slice_in_dim(crowd, start,
                                            objs_local.shape[0])

    return jax.jit(_crowd)(objs, rank)


def _sharded_gen_body(state: nsga2.NSGA2State, fitness_fn,
                      cfg: nsga2.NSGA2Config, axis: str) -> nsga2.NSGA2State:
    """One (mu+lambda) generation on a population sharded over ``axis``.

    Runs inside shard_map (optionally under a bucket-axis vmap). The state's
    population arrays are this shard's slab; ``state.key`` is replicated, so
    every shard draws identical randomness and the cheap O(P·G) selection /
    variation bookkeeping is replicated rather than communicated. Only the
    two expensive pieces are actually distributed: fitness (each shard
    evaluates its contiguous child slab; per-chromosome results are
    row-independent, so the gather reassembles exactly the monolithic
    array) and domination (hierarchical row blocks, `_hierarchical_ranks`).
    Crowding and truncation run on the replicated gathered pool with the
    exact oracle arithmetic — see `sharded_crowding_distance` for why the
    f32 adds must not be psum-reassociated. Net: bit-identical to
    `nsga2.make_step` on the gathered state (tests pin it)."""
    p_local, g = state.genes.shape
    idx0 = jax.lax.axis_index(axis)
    genes = jax.lax.all_gather(state.genes, axis, tiled=True)    # (P, G)
    objs = jax.lax.all_gather(state.objs, axis, tiled=True)      # (P, M)
    rank = jax.lax.all_gather(state.rank, axis, tiled=True)
    crowd = jax.lax.all_gather(state.crowd, axis, tiled=True)
    p = genes.shape[0]
    p_mut = cfg.p_mutation if cfg.p_mutation is not None else 1.0 / g
    key, ksel, kx, km = jax.random.split(state.key, 4)

    idx = nsga2._tournament(ksel, rank, crowd, p)
    pa, pb = genes[idx[0::2]], genes[idx[1::2]]
    o1, o2 = nsga2._sbx(kx, pa, pb, cfg.eta_crossover, cfg.p_crossover)
    children = jnp.concatenate([o1, o2], axis=0)[:p]
    children = nsga2._poly_mutation(km, children, cfg.eta_mutation, p_mut)
    # sharded fitness: each shard evaluates only its contiguous child slab
    c_local = jax.lax.dynamic_slice_in_dim(children, idx0 * p_local, p_local)
    c_objs = jax.lax.all_gather(fitness_fn(c_local), axis, tiled=True)

    pool_genes = jnp.concatenate([genes, children], axis=0)      # (2P, G)
    pool_objs = jnp.concatenate([objs, c_objs], axis=0)          # (2P, M)
    rows = 2 * p_local
    pool_local = jax.lax.dynamic_slice_in_dim(pool_objs, idx0 * rows, rows)
    pool_rank = _hierarchical_ranks(pool_local, pool_objs, axis)
    pool_crowd = nsga2.crowding_distance(pool_objs, pool_rank)
    # elitist truncation: (rank asc, crowding desc) — replicated argsort
    order = jnp.argsort(pool_rank.astype(jnp.float32) * nsga2._BIG
                        - jnp.minimum(pool_crowd, nsga2._BIG / 2))
    keep = order[:p]

    def slab(a):
        return jax.lax.dynamic_slice_in_dim(a, idx0 * p_local, p_local)

    return nsga2.NSGA2State(
        slab(pool_genes[keep]), slab(pool_objs[keep]), slab(pool_rank[keep]),
        slab(pool_crowd[keep]), key, state.generation + 1,
    )


def _check_divisible(p: int, mesh: Mesh, axis: str, what: str) -> None:
    n = mesh.shape[axis]
    if p % n:
        raise ValueError(
            f"{what} size {p} not divisible by mesh axis {axis!r} ({n})")


def _make_sharded_gen(fitness_fn, mesh: Mesh, cfg: nsga2.NSGA2Config,
                      axis: str = "pop"):
    from repro.sharding import search as _specs

    specs = _specs.search_state_specs(axis)

    @partial(shard_map, mesh=mesh, in_specs=(specs,), out_specs=specs,
             check_rep=False)
    def _gen(state: nsga2.NSGA2State) -> nsga2.NSGA2State:
        return _sharded_gen_body(state, fitness_fn, cfg, axis)

    return _gen


def make_sharded_step(fitness_fn, mesh: Mesh, cfg: nsga2.NSGA2Config,
                      axis: str = "pop"):
    """One sharded generation as a jitted program (see `_sharded_gen_body`)."""
    return jax.jit(_make_sharded_gen(fitness_fn, mesh, cfg, axis))


def make_sharded_chunk(fitness_fn, mesh: Mesh, cfg: nsga2.NSGA2Config,
                       chunk_len: int, axis: str = "pop"):
    """`nsga2.make_chunk` with the population axis sharded over ``axis``.

    One dispatch advances the whole sharded population by ``chunk_len``
    generations (the §9 device-resident loop, scanned over the shard_map'd
    generation); bit-identical to the single-device chunk on the gathered
    state."""
    if chunk_len < 1:
        raise ValueError(f"chunk_len must be >= 1, got {chunk_len}")
    gen = _make_sharded_gen(fitness_fn, mesh, cfg, axis)

    @jax.jit
    def chunk(state: nsga2.NSGA2State) -> nsga2.NSGA2State:
        return jax.lax.scan(lambda s, _: (gen(s), None), state, None,
                            length=chunk_len)[0]

    return chunk


def make_sharded_batched_chunk(fitness_from_ctx, mesh: Mesh,
                               cfg: nsga2.NSGA2Config, chunk_len: int,
                               bucket_axis: str = "bucket",
                               axis: str = "pop"):
    """`nsga2.make_batched_chunk` spread over a 2-D (bucket, pop) mesh.

    The sweep's stacked problem axis is sharded over ``bucket_axis`` and
    every problem's population over ``axis``, so one dispatch advances the
    whole campaign using the full mesh (DESIGN.md §13). The per-problem body
    is exactly `_sharded_gen_body` vmapped over the local problem slab —
    named-axis collectives batch transparently under vmap — so each lane is
    bit-identical to its `nsga2.make_chunk` serial oracle. The stacked
    problem count must divide the bucket axis (pad the stack by repeating a
    problem and drop the extra lanes — compute waste, not wrong results)."""
    if chunk_len < 1:
        raise ValueError(f"chunk_len must be >= 1, got {chunk_len}")
    from repro.sharding import search as _specs

    specs = _specs.batched_state_specs(bucket_axis, axis)

    @jax.jit
    def chunk(states: nsga2.NSGA2State, ctxs) -> nsga2.NSGA2State:
        ctx_specs = jax.tree.map(lambda _: P(bucket_axis), ctxs)

        @partial(shard_map, mesh=mesh, in_specs=(specs, ctx_specs),
                 out_specs=specs, check_rep=False)
        def _chunk(states, ctxs):
            def one(state, ctx):
                fit = lambda pop: fitness_from_ctx(ctx, pop)

                def step(s, _):
                    return _sharded_gen_body(s, fit, cfg, axis), None

                return jax.lax.scan(step, state, None, length=chunk_len)[0]

            return jax.vmap(one)(states, ctxs)

        return _chunk(states, ctxs)

    return chunk


def sharded_state_sharding(mesh: Mesh, axis: str = "pop") -> nsga2.NSGA2State:
    """Sharding pytree for a mesh-sharded global NSGA2State.

    Population arrays shard over ``axis``; the key and generation counter are
    replicated (every shard draws identical randomness — the bit-exactness
    anchor of `_sharded_gen_body`). Also what `runtime.checkpoint.restore`
    needs to re-shard a saved single-device search state onto a mesh."""
    from repro.sharding import search as _specs

    return _specs.search_state_sharding(mesh, axis)


def init_sharded(key, fitness_fn, n_genes: int, mesh: Mesh,
                 cfg: nsga2.NSGA2Config, axis: str = "pop",
                 seed_genes=None) -> nsga2.NSGA2State:
    """`nsga2.init_state` laid out sharded over ``axis``.

    Init is a one-off, so it runs the monolithic oracle and lays the result
    out over the mesh — trivially bit-identical, and the same path a
    checkpoint restore takes (`sharded_state_sharding`)."""
    _check_divisible(cfg.pop_size, mesh, axis, "population")
    state = nsga2.init_state(key, fitness_fn, n_genes, cfg,
                             seed_genes=seed_genes)
    return jax.tree.map(jax.device_put, state,
                        sharded_state_sharding(mesh, axis))


# ---------------------------------------------------------------------------
# Island model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IslandConfig:
    local_pop: int = 32          # per-island population
    migrate_every: int = 5       # generations between migrations
    n_migrate: int = 4           # elites sent around the ring
    nsga: nsga2.NSGA2Config = dataclasses.field(default_factory=nsga2.NSGA2Config)


def _local_evolve(state: nsga2.NSGA2State, fitness_fn, cfg: nsga2.NSGA2Config,
                  n_gens: int) -> nsga2.NSGA2State:
    step = nsga2.make_step(fitness_fn, cfg)
    return jax.lax.fori_loop(0, n_gens, lambda _, s: step(s), state)


def _migrate(state: nsga2.NSGA2State, axis: str, n_migrate: int,
             n_islands: int) -> nsga2.NSGA2State:
    """Ring migration of the n_migrate best; they replace the worst."""
    order = jnp.argsort(
        state.rank.astype(jnp.float32) * 1e9 - jnp.minimum(state.crowd, 5e8)
    )
    best, worst = order[:n_migrate], order[-n_migrate:]
    perm = [(i, (i + 1) % n_islands) for i in range(n_islands)]
    mig_genes = jax.lax.ppermute(state.genes[best], axis, perm)
    mig_objs = jax.lax.ppermute(state.objs[best], axis, perm)
    genes = state.genes.at[worst].set(mig_genes)
    objs = state.objs.at[worst].set(mig_objs)
    rank = nsga2.non_dominated_sort(objs)
    crowd = nsga2.crowding_distance(objs, rank)
    return nsga2.NSGA2State(genes, objs, rank, crowd, state.key, state.generation)


def _make_round(fitness_fn, mesh: Mesh, cfg: IslandConfig, axis: str = "data"):
    """Unjitted one-round body shared by make_island_step / make_island_chunk."""
    pspec = P(axis)
    state_specs = nsga2.NSGA2State(
        genes=pspec, objs=pspec, rank=pspec, crowd=pspec, key=pspec,
        generation=P(),
    )

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(state_specs,),
        out_specs=state_specs,
        check_rep=False,
    )
    def _round(state: nsga2.NSGA2State) -> nsga2.NSGA2State:
        local = nsga2.NSGA2State(
            state.genes, state.objs, state.rank, state.crowd,
            state.key[0], state.generation,
        )
        local = _local_evolve(local, fitness_fn, cfg.nsga, cfg.migrate_every)
        local = _migrate(local, axis, cfg.n_migrate, mesh.shape[axis])
        return nsga2.NSGA2State(
            local.genes, local.objs, local.rank, local.crowd,
            local.key[None], local.generation,
        )

    return _round


def make_island_step(fitness_fn, mesh: Mesh, cfg: IslandConfig, axis: str = "data"):
    """One migration round: `migrate_every` local generations + ring exchange.

    State arrays are sharded over `axis`: genes (n_islands*local_pop, G).
    """
    return jax.jit(_make_round(fitness_fn, mesh, cfg, axis))


def make_island_chunk(fitness_fn, mesh: Mesh, cfg: IslandConfig, n_rounds: int,
                      axis: str = "data"):
    """`n_rounds` migration rounds as ONE dispatch: lax.scan over the round.

    The island analogue of `nsga2.make_chunk` (DESIGN.md §9): the host
    dispatches once per checkpoint interval instead of once per round; the
    scan body is exactly the `make_island_step` round, so chunked and
    per-round execution are bit-identical."""
    if n_rounds < 1:
        raise ValueError(f"n_rounds must be >= 1, got {n_rounds}")
    round_fn = _make_round(fitness_fn, mesh, cfg, axis)

    @jax.jit
    def chunk(state: nsga2.NSGA2State) -> nsga2.NSGA2State:
        return jax.lax.scan(lambda s, _: (round_fn(s), None), state, None,
                            length=n_rounds)[0]

    return chunk


def init_islands(key, fitness_fn, n_genes: int, mesh: Mesh, cfg: IslandConfig,
                 axis: str = "data", seed_genes=None) -> nsga2.NSGA2State:
    """Initialize per-island states, already laid out sharded over `axis`.

    seed_genes: optional known-good designs injected into every island's
    initial population (see nsga2.init_state)."""
    n_islands = mesh.shape[axis]
    keys = jax.random.split(key, n_islands)
    local_cfg = dataclasses.replace(cfg.nsga, pop_size=cfg.local_pop)

    def one(k):
        return nsga2.init_state(k, fitness_fn, n_genes, local_cfg,
                                seed_genes=seed_genes)

    states = [one(k) for k in keys]
    genes = jnp.concatenate([s.genes for s in states])
    objs = jnp.concatenate([s.objs for s in states])
    rank = jnp.concatenate([s.rank for s in states])
    crowd = jnp.concatenate([s.crowd for s in states])
    key_arr = jnp.stack([s.key for s in states])
    state = nsga2.NSGA2State(genes, objs, rank, crowd, key_arr, jnp.int32(0))

    shard = NamedSharding(mesh, P(axis))
    return nsga2.NSGA2State(
        jax.device_put(state.genes, shard),
        jax.device_put(state.objs, shard),
        jax.device_put(state.rank, shard),
        jax.device_put(state.crowd, shard),
        jax.device_put(state.key, shard),
        state.generation,
    )


def island_state_sharding(mesh: Mesh, axis: str = "data") -> nsga2.NSGA2State:
    """Sharding pytree matching an island NSGA2State (elastic restore)."""
    shard = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())
    return nsga2.NSGA2State(genes=shard, objs=shard, rank=shard, crowd=shard,
                            key=shard, generation=rep)


def run_islands(key, fitness_fn, n_genes: int, mesh: Mesh, cfg: IslandConfig,
                n_rounds: int, axis: str = "data",
                state: nsga2.NSGA2State | None = None,
                seed_genes=None) -> nsga2.NSGA2State:
    """All `n_rounds` rounds in one device dispatch (chunked scan)."""
    if state is None:
        state = init_islands(key, fitness_fn, n_genes, mesh, cfg, axis,
                             seed_genes)
    if n_rounds > 0:
        state = make_island_chunk(fitness_fn, mesh, cfg, n_rounds, axis)(state)
    return state


def gathered_pareto(state: nsga2.NSGA2State):
    """Global pareto front across all islands."""
    return nsga2.pareto_front(jax.device_get(state.objs), jax.device_get(state.genes))
