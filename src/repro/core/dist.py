"""Distributed NSGA-II: population sharding + island model across the mesh.

Two levels, matching DESIGN.md §6:

1. `sharded_fitness` — data-parallel fitness: the population tensor is sharded
   over mesh axes; each device evaluates its slice against the (replicated)
   dataset. The GA bookkeeping (P×P domination, selection) happens on the
   gathered objectives — tiny (P×2).

2. `island_step` / `run_islands` — one NSGA-II *island* per mesh group (pods
   at production scale). Islands evolve independently (zero cross-pod traffic
   in the inner loop) and exchange elites via a `ppermute` ring every
   `migrate_every` generations. A dead pod costs search breadth, not
   correctness — the fault-tolerance story for the GA workload.

Rounds are device-resident: `make_island_chunk` scans whole checkpoint
intervals in one dispatch (DESIGN.md §9), and `island_state_sharding` gives
the sharding pytree `runtime.checkpoint.restore` needs to re-shard a saved
island state onto the current mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import nsga2


def sharded_fitness(fitness_fn, mesh: Mesh, axis: str = "data"):
    """Wrap a (P, G) -> (P, M) fitness so the population axis is sharded."""
    pspec = P(axis)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(pspec,),
        out_specs=pspec,
        check_rep=False,
    )
    def _eval(genes):
        return fitness_fn(genes)

    @jax.jit
    def eval_sharded(genes):
        return _eval(genes)

    return eval_sharded


# ---------------------------------------------------------------------------
# Island model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IslandConfig:
    local_pop: int = 32          # per-island population
    migrate_every: int = 5       # generations between migrations
    n_migrate: int = 4           # elites sent around the ring
    nsga: nsga2.NSGA2Config = dataclasses.field(default_factory=nsga2.NSGA2Config)


def _local_evolve(state: nsga2.NSGA2State, fitness_fn, cfg: nsga2.NSGA2Config,
                  n_gens: int) -> nsga2.NSGA2State:
    step = nsga2.make_step(fitness_fn, cfg)
    return jax.lax.fori_loop(0, n_gens, lambda _, s: step(s), state)


def _migrate(state: nsga2.NSGA2State, axis: str, n_migrate: int,
             n_islands: int) -> nsga2.NSGA2State:
    """Ring migration of the n_migrate best; they replace the worst."""
    order = jnp.argsort(
        state.rank.astype(jnp.float32) * 1e9 - jnp.minimum(state.crowd, 5e8)
    )
    best, worst = order[:n_migrate], order[-n_migrate:]
    perm = [(i, (i + 1) % n_islands) for i in range(n_islands)]
    mig_genes = jax.lax.ppermute(state.genes[best], axis, perm)
    mig_objs = jax.lax.ppermute(state.objs[best], axis, perm)
    genes = state.genes.at[worst].set(mig_genes)
    objs = state.objs.at[worst].set(mig_objs)
    rank = nsga2.non_dominated_sort(objs)
    crowd = nsga2.crowding_distance(objs, rank)
    return nsga2.NSGA2State(genes, objs, rank, crowd, state.key, state.generation)


def _make_round(fitness_fn, mesh: Mesh, cfg: IslandConfig, axis: str = "data"):
    """Unjitted one-round body shared by make_island_step / make_island_chunk."""
    pspec = P(axis)
    state_specs = nsga2.NSGA2State(
        genes=pspec, objs=pspec, rank=pspec, crowd=pspec, key=pspec,
        generation=P(),
    )

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(state_specs,),
        out_specs=state_specs,
        check_rep=False,
    )
    def _round(state: nsga2.NSGA2State) -> nsga2.NSGA2State:
        local = nsga2.NSGA2State(
            state.genes, state.objs, state.rank, state.crowd,
            state.key[0], state.generation,
        )
        local = _local_evolve(local, fitness_fn, cfg.nsga, cfg.migrate_every)
        local = _migrate(local, axis, cfg.n_migrate, mesh.shape[axis])
        return nsga2.NSGA2State(
            local.genes, local.objs, local.rank, local.crowd,
            local.key[None], local.generation,
        )

    return _round


def make_island_step(fitness_fn, mesh: Mesh, cfg: IslandConfig, axis: str = "data"):
    """One migration round: `migrate_every` local generations + ring exchange.

    State arrays are sharded over `axis`: genes (n_islands*local_pop, G).
    """
    return jax.jit(_make_round(fitness_fn, mesh, cfg, axis))


def make_island_chunk(fitness_fn, mesh: Mesh, cfg: IslandConfig, n_rounds: int,
                      axis: str = "data"):
    """`n_rounds` migration rounds as ONE dispatch: lax.scan over the round.

    The island analogue of `nsga2.make_chunk` (DESIGN.md §9): the host
    dispatches once per checkpoint interval instead of once per round; the
    scan body is exactly the `make_island_step` round, so chunked and
    per-round execution are bit-identical."""
    if n_rounds < 1:
        raise ValueError(f"n_rounds must be >= 1, got {n_rounds}")
    round_fn = _make_round(fitness_fn, mesh, cfg, axis)

    @jax.jit
    def chunk(state: nsga2.NSGA2State) -> nsga2.NSGA2State:
        return jax.lax.scan(lambda s, _: (round_fn(s), None), state, None,
                            length=n_rounds)[0]

    return chunk


def init_islands(key, fitness_fn, n_genes: int, mesh: Mesh, cfg: IslandConfig,
                 axis: str = "data", seed_genes=None) -> nsga2.NSGA2State:
    """Initialize per-island states, already laid out sharded over `axis`.

    seed_genes: optional known-good designs injected into every island's
    initial population (see nsga2.init_state)."""
    n_islands = mesh.shape[axis]
    keys = jax.random.split(key, n_islands)
    local_cfg = dataclasses.replace(cfg.nsga, pop_size=cfg.local_pop)

    def one(k):
        return nsga2.init_state(k, fitness_fn, n_genes, local_cfg,
                                seed_genes=seed_genes)

    states = [one(k) for k in keys]
    genes = jnp.concatenate([s.genes for s in states])
    objs = jnp.concatenate([s.objs for s in states])
    rank = jnp.concatenate([s.rank for s in states])
    crowd = jnp.concatenate([s.crowd for s in states])
    key_arr = jnp.stack([s.key for s in states])
    state = nsga2.NSGA2State(genes, objs, rank, crowd, key_arr, jnp.int32(0))

    shard = NamedSharding(mesh, P(axis))
    return nsga2.NSGA2State(
        jax.device_put(state.genes, shard),
        jax.device_put(state.objs, shard),
        jax.device_put(state.rank, shard),
        jax.device_put(state.crowd, shard),
        jax.device_put(state.key, shard),
        state.generation,
    )


def island_state_sharding(mesh: Mesh, axis: str = "data") -> nsga2.NSGA2State:
    """Sharding pytree matching an island NSGA2State (elastic restore)."""
    shard = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())
    return nsga2.NSGA2State(genes=shard, objs=shard, rank=shard, crowd=shard,
                            key=shard, generation=rep)


def run_islands(key, fitness_fn, n_genes: int, mesh: Mesh, cfg: IslandConfig,
                n_rounds: int, axis: str = "data",
                state: nsga2.NSGA2State | None = None,
                seed_genes=None) -> nsga2.NSGA2State:
    """All `n_rounds` rounds in one device dispatch (chunked scan)."""
    if state is None:
        state = init_islands(key, fitness_fn, n_genes, mesh, cfg, axis,
                             seed_genes)
    if n_rounds > 0:
        state = make_island_chunk(fitness_fn, mesh, cfg, n_rounds, axis)(state)
    return state


def gathered_pareto(state: nsga2.NSGA2State):
    """Global pareto front across all islands."""
    return nsga2.pareto_front(jax.device_get(state.objs), jax.device_get(state.genes))
