"""The paper's primary contribution: approximate bespoke Decision Trees.

- train.py  CART training (gini, expand-until-pure)
- tree.py   flattened trees + parallel comparator-array form (TPU dataflow)
- quant.py  precision-conversion module (paper Fig. 3b)
- area.py   comparator gate model + Area LUT (paper Fig. 4) + power model
- approx.py dual approximation chromosome -> (accuracy loss, area) fitness
- nsga2.py  vectorized NSGA-II (paper §III-B)
- dist.py   population sharding + island-model GA across pods
- rtl.py    bespoke Verilog emission (paper §III synthesis front-end)
"""
from repro.core import approx, area, nsga2, quant, rtl, tree, train

__all__ = ["approx", "area", "nsga2", "quant", "rtl", "tree", "train"]
