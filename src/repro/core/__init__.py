"""The paper's primary contribution: approximate bespoke Decision Trees.

The bottom layer of the repo's architecture (DESIGN.md §1).

- train.py  CART training (gini, expand-until-pure)
- tree.py   flattened trees + parallel comparator-array form (TPU dataflow)
- quant.py  precision-conversion module (paper Fig. 3b)
- area.py   comparator gate model + Area LUT (paper Fig. 4) + power model
- approx.py dual approximation fitness (thin adapter over repro.search)
- forest.py random-forest trainer + per-tree oracle + CSE area
- nsga2.py  vectorized NSGA-II (paper §III-B)
- dist.py   population sharding + island-model GA across pods
- netlist.py gate-level netlist IR + batched circuit simulator (DESIGN.md §10)
- rtl.py    bespoke Verilog emission, trees + forests (printed from netlist
            cells; paper §III synthesis front-end)

Design-space *search* (tree and forest alike) lives in `repro.search`:
one SearchProblem + pluggable reference/kernel/islands backends behind
`run_search` (DESIGN.md §7).
"""
from repro.core import area, netlist, nsga2, quant, rtl, tree, train

__all__ = ["approx", "area", "forest", "netlist", "nsga2", "quant", "rtl",
           "tree", "train"]


def __getattr__(name):
    # approx/forest adapt over repro.search, which itself imports repro.core:
    # loading them lazily (PEP 562) keeps `from repro.core import approx`
    # working from either entry point without a circular import.
    if name in ("approx", "forest"):
        import importlib
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
