"""Random-Forest extension (beyond paper, same machinery).

The paper targets single Decision Trees but names Random Forests among the
hardware-friendly classifier families ([1] evaluates them). A bespoke RF is
K parallel bespoke trees + a majority-vote adder tree — so the dual
approximation applies per comparator across the WHOLE forest with one
chromosome of 3*sum_k(N_k)+1 genes (DESIGN.md §16), and cross-tree
comparator sharing (CSE)
makes the joint search strictly richer than per-tree searches: moving two
trees' thresholds to the SAME hardware-friendly value collapses them into
one comparator.

Forest *search* now runs through the unified engine in `repro.search`
(DESIGN.md §7): `build_forest_problem(forest, ...)` lays the forest out as
one block-diagonal super-tree whose vote matmul evaluates every tree in a
single fused tensor program (or ONE Pallas kernel launch with
`backend="kernel"`), instead of this module's historical K-iteration Python
loop. `forest_predict` below is retained as the per-tree *oracle* the fused
paths are bit-exactness-tested against; `make_forest_fitness` is a thin
adapter over the engine's reference backend. Area scoring with cross-tree
CSE (`forest_area_mm2`) stays here.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import area as area_mod, quant
from repro.core.train import TreeArrays, train_tree
from repro.core.tree import ParallelTree, to_parallel, leaves_from_decisions


@dataclasses.dataclass
class Forest:
    trees: list[TreeArrays]
    ptrees: list[ParallelTree]
    n_classes: int

    @property
    def n_comparators(self) -> int:
        return sum(p.n_comparators for p in self.ptrees)

    @property
    def n_genes(self) -> int:
        # cross-layer layout (DESIGN.md §16): 3 genes per comparator plus
        # the forest-level vote-adder gene
        return 3 * self.n_comparators + 1


def train_forest(x, y, n_classes, n_trees=5, seed=0, feature_frac=0.7):
    """Bootstrap-sampled trees over random feature subsets (classic RF)."""
    rng = np.random.default_rng(seed)
    n, f = x.shape
    trees = []
    for _ in range(n_trees):
        idx = rng.integers(0, n, n)
        feats = rng.permutation(f)[: max(1, int(f * feature_frac))]
        xb = np.zeros_like(x)
        xb[:, feats] = x[idx][:, feats]
        trees.append(train_tree(xb, y[idx], n_classes))
    return Forest(trees, [to_parallel(t) for t in trees], n_classes)


def forest_predict(forest: Forest, x8, bits_all, marg_all):
    """Majority vote over quantized trees — the sequential per-tree ORACLE.

    Evaluates trees one by one in a Python loop (K small programs). Kept as
    the reference the fused paths (`repro.search` reference backend and the
    block-diagonal Pallas kernel) are bit-exactness-tested against; use those
    for anything performance-sensitive. bits/marg: concatenated per-tree
    comparator genes (decoded)."""
    votes = jnp.zeros((x8.shape[0], forest.n_classes), jnp.float32)
    off = 0
    for pt in forest.ptrees:
        n = pt.n_comparators
        bits = bits_all[off:off + n]
        marg = marg_all[off:off + n]
        t_int = quant.substitute(
            quant.threshold_to_int(jnp.asarray(pt.threshold), bits), marg, bits)
        x_g = x8[:, jnp.asarray(pt.feature)]
        x_p = quant.inputs_at_precision(x_g, bits)
        d = x_p > t_int[None, :]
        leaf = leaves_from_decisions(d, jnp.asarray(pt.path),
                                     jnp.asarray(pt.path_len))
        cls = jnp.asarray(pt.leaf_class)[leaf]
        votes = votes + jax.nn.one_hot(cls, forest.n_classes)
        off += n
    return jnp.argmax(votes, axis=1)


def forest_area_mm2(forest: Forest, bits_all, marg_all, dedup=True) -> float:
    """CSE'd area across ALL trees: identical (feature, t', p) comparators
    are shared forest-wide, exactly like DC synthesis of the flat netlist."""
    feats, t_ints, bits_np = [], [], []
    off = 0
    bits_all = np.asarray(bits_all)
    marg_all = np.asarray(marg_all)
    for pt in forest.ptrees:
        n = pt.n_comparators
        b = bits_all[off:off + n]
        t = np.clip(np.floor(pt.threshold * (2.0 ** b)), 0, (1 << b) - 1)
        t = np.clip(t + marg_all[off:off + n], 0, (1 << b) - 1)
        feats.append(pt.feature)
        t_ints.append(t.astype(np.int64))
        bits_np.append(b)
        off += n
    area = area_mod.tree_area_mm2(
        np.concatenate(feats), np.concatenate(t_ints),
        np.concatenate(bits_np),
        sum(p.n_leaves for p in forest.ptrees), dedup=dedup)
    return float(area)


def make_forest_fitness(forest: Forest, x_test, y_test):
    """(P, 3*N_total+1) genes -> (P, 2) objectives (accuracy loss, norm area).

    Thin adapter over the unified engine: builds the block-diagonal
    `SearchProblem` for this forest and returns its reference-backend fitness
    (one fused vote-matmul program per population — no per-tree loop), plus
    the exact-design (accuracy, area) reference the objectives normalize by.
    Pass the same problem to `repro.search.run_search` for the kernel/island
    backends, checkpointing and artifacts.
    """
    from repro.search import build_forest_problem, make_reference_fitness

    problem = build_forest_problem(forest, x_test, y_test)
    return (make_reference_fitness(problem), problem.exact_accuracy,
            problem.exact_area_mm2)
