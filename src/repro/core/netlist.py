"""Gate-level netlist IR for bespoke tree/forest circuits (DESIGN.md §10).

The one lowering every hardware artifact derives from: a tree (or forest)
plus a decoded chromosome — per-comparator precision and substituted integer
threshold — becomes an explicit netlist of 2-input printed gates:

  comparator cells  hard-wired ``X > t'`` chains, one AND2/OR2 per significant
                    bit above the lowest set bit of ``t' + 1`` — the SAME
                    construction `core.area.comparator_gate_counts` prices, so
                    gate counts and the area LUT cannot drift apart;
  path-AND cells    one AND tree per leaf over comparator literals;
  class-OR cells    per-class one-hot vote wires (OR of the class's leaves);
  vote adders       forests only: a popcount adder tree per class — §2's vote
                    matmul in hardware — plus an argmax comparator chain with
                    first-max tie-breaking (matching `jnp.argmax`).

Construction is hash-consed (structural CSE, like DC synthesis of the flat
bespoke netlist: identical comparators — within or across trees — share
hardware) with constant propagation (a ``t' = 2^p - 1`` comparator folds to
constant false and its dead path logic vanishes). From the finished
`Circuit`:

  - `simulate(circuit, x8)` evaluates the whole test set in one vectorized,
    `lax.scan`-free jnp pass (gates grouped by logic level, one masked
    gather/op per level) — the hardware oracle `core.rtl` emission is
    verified against;
  - `gate_counts(circuit)` / `netlist_area_mm2(circuit)` give the
    synthesized-netlist "actual" area the GA's additive-LUT estimate is
    measured against (the paper's Fig. 5 estimated-vs-actual gap).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import area as area_mod
from repro.core.tree import ParallelTree

# gate opcodes; CONST0/CONST1 are always gates 0 and 1 of every netlist
CONST0, CONST1, INPUT, NOT, AND, OR, XOR = range(7)
OP_NAMES = ("const0", "const1", "input", "not", "and", "or", "xor")
MASTER_BITS = 8


class NetlistBuilder:
    """Hash-consed gate builder with constant folding.

    Gate ids are topologically ordered by construction (operands always
    precede their gate), so a single linear pass levelizes the netlist.
    """

    def __init__(self):
        self.op: list[int] = []
        self.a: list[int] = []
        self.b: list[int] = []
        self._cache: dict[tuple[int, int, int], int] = {}
        self.zero = self._raw(CONST0, -1, -1)   # gate 0
        self.one = self._raw(CONST1, -1, -1)    # gate 1

    def _raw(self, op: int, a: int, b: int) -> int:
        key = (op, a, b)
        gid = self._cache.get(key)
        if gid is None:
            gid = len(self.op)
            self.op.append(op)
            self.a.append(a)
            self.b.append(b)
            self._cache[key] = gid
        return gid

    # -- primitives with folding -------------------------------------------
    def input_bit(self, feature: int, bit: int) -> int:
        """Bit `bit` (LSB = 0) of feature `feature`'s 8-bit master code."""
        return self._raw(INPUT, int(feature), int(bit))

    def not_(self, x: int) -> int:
        if x == self.zero:
            return self.one
        if x == self.one:
            return self.zero
        if self.op[x] == NOT:           # ~~x = x
            return self.a[x]
        return self._raw(NOT, x, -1)

    def _is_complement(self, x: int, y: int) -> bool:
        return (self.op[y] == NOT and self.a[y] == x) or (
            self.op[x] == NOT and self.a[x] == y)

    def and_(self, x: int, y: int) -> int:
        if x == y:
            return x
        if x == self.zero or y == self.zero:
            return self.zero
        if x == self.one:
            return y
        if y == self.one:
            return x
        if self._is_complement(x, y):
            return self.zero
        if x > y:                       # commutative normal form
            x, y = y, x
        return self._raw(AND, x, y)

    def or_(self, x: int, y: int) -> int:
        if x == y:
            return x
        if x == self.one or y == self.one:
            return self.one
        if x == self.zero:
            return y
        if y == self.zero:
            return x
        if self._is_complement(x, y):
            return self.one
        if x > y:
            x, y = y, x
        return self._raw(OR, x, y)

    def xor_(self, x: int, y: int) -> int:
        if x == y:
            return self.zero
        if x == self.zero:
            return y
        if y == self.zero:
            return x
        if x == self.one:
            return self.not_(y)
        if y == self.one:
            return self.not_(x)
        if self._is_complement(x, y):
            return self.one
        if x > y:
            x, y = y, x
        return self._raw(XOR, x, y)

    def _reduce(self, wires: list[int], fn) -> int:
        """Balanced binary reduction (minimizes logic depth/sim levels)."""
        if not wires:
            raise ValueError("empty reduction")
        while len(wires) > 1:
            nxt = [fn(wires[i], wires[i + 1])
                   for i in range(0, len(wires) - 1, 2)]
            if len(wires) % 2:
                nxt.append(wires[-1])
            wires = nxt
        return wires[0]

    def and_many(self, wires: list[int]) -> int:
        return self._reduce(list(wires), self.and_) if wires else self.one

    def or_many(self, wires: list[int]) -> int:
        return self._reduce(list(wires), self.or_) if wires else self.zero

    # -- comparator lowering (mirrors core.area.comparator_gate_counts) ----
    def comparator(self, feature: int, t_int: int, p: int) -> int:
        """Hard-wired ``X > t'`` where X is the top `p` master-code bits.

        ``X > t  ==  X >= u`` with ``u = t + 1``; scanning u from the LSB,
        the lowest set bit j contributes ``g = X_j`` for free, and every
        higher bit exactly one gate (u_i = 1 -> AND, u_i = 0 -> OR) — the
        same count `core.area.comparator_gate_counts` prices. ``u = 2^p``
        (t' = 2^p - 1) is constant false."""
        u = int(t_int) + 1
        if u >= (1 << p):
            return self.zero
        tz = (u & -u).bit_length() - 1          # trailing zeros of u
        # truncated bit j of X is master bit (8 - p + j)
        g = self.input_bit(feature, MASTER_BITS - p + tz)
        for i in range(tz + 1, p):
            xi = self.input_bit(feature, MASTER_BITS - p + i)
            g = self.and_(xi, g) if (u >> i) & 1 else self.or_(xi, g)
        return g

    # -- arithmetic (vote adder tree + argmax chain) -----------------------
    def full_add(self, x: int, y: int, c: int) -> tuple[int, int]:
        s1 = self.xor_(x, y)
        return self.xor_(s1, c), self.or_(self.and_(x, y), self.and_(s1, c))

    def add(self, a_bits: list[int], b_bits: list[int]) -> list[int]:
        """Ripple-carry add of LSB-first vectors; result carries the overflow
        bit, so popcounts never wrap."""
        n = max(len(a_bits), len(b_bits))
        a_bits = list(a_bits) + [self.zero] * (n - len(a_bits))
        b_bits = list(b_bits) + [self.zero] * (n - len(b_bits))
        out, carry = [], self.zero
        for x, y in zip(a_bits, b_bits):
            s, carry = self.full_add(x, y, carry)
            out.append(s)
        out.append(carry)
        return out

    def popcount(self, wires: list[int]) -> list[int]:
        """LSB-first bit-vector count of set wires (balanced adder tree)."""
        if not wires:
            return [self.zero]
        vecs = [[w] for w in wires]
        while len(vecs) > 1:
            nxt = [self.add(vecs[i], vecs[i + 1])
                   for i in range(0, len(vecs) - 1, 2)]
            if len(vecs) % 2:
                nxt.append(vecs[-1])
            vecs = nxt
        return vecs[0]

    def gt(self, a_bits: list[int], b_bits: list[int]) -> int:
        """Unsigned a > b over LSB-first vectors."""
        n = max(len(a_bits), len(b_bits))
        a_bits = list(a_bits) + [self.zero] * (n - len(a_bits))
        b_bits = list(b_bits) + [self.zero] * (n - len(b_bits))
        g = self.zero
        for x, y in zip(a_bits, b_bits):        # LSB -> MSB
            gt_i = self.and_(x, self.not_(y))
            eq_i = self.not_(self.xor_(x, y))
            g = self.or_(gt_i, self.and_(eq_i, g))
        return g

    def mux_vec(self, sel: int, a_bits: list[int],
                b_bits: list[int]) -> list[int]:
        """sel ? a : b, bitwise; vectors padded to equal width."""
        n = max(len(a_bits), len(b_bits))
        a_bits = list(a_bits) + [self.zero] * (n - len(a_bits))
        b_bits = list(b_bits) + [self.zero] * (n - len(b_bits))
        ns = self.not_(sel)
        return [self.or_(self.and_(sel, x), self.and_(ns, y))
                for x, y in zip(a_bits, b_bits)]

    def const_vec(self, value: int, width: int) -> list[int]:
        return [self.one if (value >> i) & 1 else self.zero
                for i in range(width)]

    def sub(self, a_bits: list[int], b_bits: list[int]) -> list[int]:
        """Unsigned a - b over LSB-first vectors as ``a + ~b + 1``.

        Valid (wrap-free) only when a >= b; callers mask the result behind a
        `gt`/`mux_vec` select so the wrapped case is never observed — the
        printed-MLP ReLU cell does exactly that (DESIGN.md §15)."""
        n = max(len(a_bits), len(b_bits))
        a_bits = list(a_bits) + [self.zero] * (n - len(a_bits))
        b_bits = list(b_bits) + [self.zero] * (n - len(b_bits))
        out, carry = [], self.one          # +1 of the two's complement
        for x, y in zip(a_bits, b_bits):
            s, carry = self.full_add(x, self.not_(y), carry)
            out.append(s)
        return out                          # final carry dropped (a >= b)

    def sum_vecs(self, vecs: list) -> list[int]:
        """Balanced adder tree over LSB-first bit-vectors (MAC accumulate)."""
        if not vecs:
            return [self.zero]
        vecs = [list(v) for v in vecs]
        while len(vecs) > 1:
            nxt = [self.add(vecs[i], vecs[i + 1])
                   for i in range(0, len(vecs) - 1, 2)]
            if len(vecs) % 2:
                nxt.append(vecs[-1])
            vecs = nxt
        return vecs[0]


# ---------------------------------------------------------------------------
# cells: the structure `core.rtl` prints and the simulator verifies
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ComparatorCell:
    """One lowered comparator. `bits`/`t_int` are the EFFECTIVE width and
    substituted threshold the hardware implements — for a k-LSB-truncated
    cell (DESIGN.md §16) that is (p - k, t' >> k); `trunc` records k for
    provenance. `core.rtl` prints cells verbatim, so emitted Verilog is
    always the effective (truncated) comparator."""

    feature: int
    bits: int
    t_int: int      # SUBSTITUTED integer threshold t' (effective)
    wire: int       # == 0 (CONST0) when t' = 2^p - 1 folds the cell away
    trunc: int = 0  # LSB stages dropped from the requested-width cell


@dataclasses.dataclass
class LeafCell:
    literals: list  # [(comparator index, positive: bool), ...]
    leaf_class: int
    wire: int


@dataclasses.dataclass
class TreeCells:
    comparators: list  # [ComparatorCell]
    leaves: list       # [LeafCell]
    votes: list        # per-class one-hot vote wires (OR of own leaves)


@dataclasses.dataclass
class Circuit:
    """A finished netlist: frozen gate arrays + the cell structure."""

    op: np.ndarray        # int8[G]
    a: np.ndarray         # int32[G]
    b: np.ndarray         # int32[G]
    out_bits: tuple       # class-index wires, LSB first
    trees: list           # [TreeCells]
    n_classes: int

    @property
    def n_gates(self) -> int:
        return int(self.op.shape[0])

    @property
    def n_trees(self) -> int:
        return len(self.trees)


def class_bits(n_classes: int) -> int:
    return max(1, int(np.ceil(np.log2(max(n_classes, 2)))))


def build_tree_cells(nb: NetlistBuilder, pt: ParallelTree, bits, t_int,
                     n_classes: int, trunc=None) -> TreeCells:
    """Lower one tree's comparators/leaves/votes into the shared builder.

    `trunc` (optional, per-comparator int array) drops the k lowest stages
    of each comparator chain (DESIGN.md §16): the cell lowered is the exact
    comparator at width `bits - k` against `t_int >> k` — the construction
    `core.area.trunc_comparator_gate_counts` prices, so truncated gate
    counts and the area LUT cannot drift apart either.
    """
    bits = np.asarray(bits)
    t_int = np.asarray(t_int)
    trunc = (np.zeros_like(bits) if trunc is None else np.asarray(trunc))
    comps = []
    for c in range(pt.n_comparators):
        k = int(trunc[c])
        p_eff = max(int(bits[c]) - k, 0)
        t_eff = int(t_int[c]) >> k
        comps.append(ComparatorCell(
            int(pt.feature[c]), p_eff, t_eff,
            nb.comparator(int(pt.feature[c]), t_eff, p_eff), trunc=k))
    leaves = []
    for l in range(pt.n_leaves):
        lits = [(c, int(pt.path[l, c]) == 1)
                for c in range(pt.n_comparators) if int(pt.path[l, c]) != 0]
        wire = nb.and_many(
            [comps[c].wire if pos else nb.not_(comps[c].wire)
             for c, pos in lits])
        leaves.append(LeafCell(lits, int(pt.leaf_class[l]), wire))
    votes = [nb.or_many([lf.wire for lf in leaves if lf.leaf_class == c])
             for c in range(n_classes)]
    return TreeCells(comps, leaves, votes)


def build_circuit(ptrees, bits, t_int, n_classes: int, trunc=None,
                  vote_adder: str = "exact") -> Circuit:
    """Tree/forest + decoded chromosome -> verified-hardware netlist.

    `bits`/`t_int` are concatenated per-comparator arrays across the K trees
    (the `SearchProblem` chromosome layout); `trunc` optionally truncates
    each comparator's k lowest stages (DESIGN.md §16). K = 1 skips the vote
    adders: the one-hot votes binary-encode directly (exactly one leaf
    fires), and `vote_adder` is inert. K > 1 builds the vote stage selected
    by `vote_adder`:

      "exact"  per-class popcount adder tree — majority vote;
      "approx" per-class saturating OR-tree (1-bit "did ANY tree vote c"),
               the cross-layer paper's approximate vote adder.

    Either way the argmax comparator chain keeps first-max tie-breaking —
    bit-identical to `predict_votes`' `jnp.argmax` over (possibly
    saturated) vote counts.
    """
    if vote_adder not in ("exact", "approx"):
        raise ValueError(f"unknown vote_adder {vote_adder!r}")
    if isinstance(ptrees, ParallelTree):
        ptrees = [ptrees]
    bits = np.asarray(bits)
    t_int = np.asarray(t_int)
    trunc = (np.zeros_like(bits) if trunc is None else np.asarray(trunc))
    nb = NetlistBuilder()
    trees, off = [], 0
    for pt in ptrees:
        n = pt.n_comparators
        trees.append(build_tree_cells(nb, pt, bits[off:off + n],
                                      t_int[off:off + n], n_classes,
                                      trunc=trunc[off:off + n]))
        off += n
    if off != bits.shape[0]:
        raise ValueError(
            f"chromosome covers {bits.shape[0]} comparators, trees have {off}")

    n_bits = class_bits(n_classes)
    if len(trees) == 1:
        # one-hot votes -> binary class index (exactly one leaf fires)
        out = [nb.or_many([trees[0].votes[c] for c in range(n_classes)
                           if (c >> b) & 1]) for b in range(n_bits)]
    else:
        out = _vote_argmax(nb, trees, n_classes, approx=vote_adder == "approx")
    return Circuit(
        op=np.asarray(nb.op, np.int8),
        a=np.asarray(nb.a, np.int32),
        b=np.asarray(nb.b, np.int32),
        out_bits=tuple(out[:n_bits]),
        trees=trees,
        n_classes=int(n_classes),
    )


def _vote_argmax(nb: NetlistBuilder, trees, n_classes: int,
                 approx: bool) -> list:
    """Forest vote stage: per-class counts + first-max argmax chain.

    Exact mode counts votes with popcount adder trees; approx mode
    saturates each class to the 1-bit OR of its votes (DESIGN.md §16) —
    the argmax chain is shared, operating on 1-bit "counts"."""
    n_bits = class_bits(n_classes)
    if approx:
        counts = [[nb.or_many([t.votes[c] for t in trees])]
                  for c in range(n_classes)]
    else:
        counts = [nb.popcount([t.votes[c] for t in trees])
                  for c in range(n_classes)]
    best_cnt, best_idx = counts[0], nb.const_vec(0, n_bits)
    for c in range(1, n_classes):
        sel = nb.gt(counts[c], best_cnt)
        best_cnt = nb.mux_vec(sel, counts[c], best_cnt)
        best_idx = nb.mux_vec(sel, nb.const_vec(c, n_bits), best_idx)
    return best_idx


def vote_adder_gate_counts(n_trees: int, n_classes: int,
                           approx: bool) -> tuple[int, int, int, int]:
    """(n_and, n_or, n_not, n_xor) of an ISOLATED forest vote stage.

    Builds the vote stage on free-standing input wires (one per
    tree x class) and inventories its gates — the number `core.area.
    vote_adder_units` prices, so the GA's vote-adder area quanta come from
    the same lowering `build_circuit` emits. An isolated stage can't share
    logic with tree cells, so (like the additive comparator LUT) this is
    the pre-CSE estimate the netlist "actual" area is measured against.
    """
    nb = NetlistBuilder()
    trees = [TreeCells([], [], [nb.input_bit(k, c) for c in range(n_classes)])
             for k in range(n_trees)]
    _vote_argmax(nb, trees, n_classes, approx=approx)
    op = np.asarray(nb.op)
    return (int((op == AND).sum()), int((op == OR).sum()),
            int((op == NOT).sum()), int((op == XOR).sum()))


# ---------------------------------------------------------------------------
# printed-MLP cells (DESIGN.md §15): MAC rows + ReLU + signed argmax
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MacNeuronCell:
    """One integer-weight neuron: shifted-copy MAC rows + an activation cell.

    The signed accumulator is kept as an unsigned (pos, neg) pair — positive
    and negative MAC contributions summed separately — so no sign bit ever
    exists in hardware: ReLU is ``pos > neg ? pos - neg : 0`` and the output
    argmax compares ``pos_c + neg_best`` against ``pos_best + neg_c``."""

    weights: list       # effective signed integer weights, one per input
    relu: bool          # hidden neurons apply ReLU + the static right shift
    pos: list           # unsigned positive-sum wires, LSB first
    neg: list           # unsigned negative-sum wires, LSB first
    out: list           # activation output wires (ReLU'd + shifted), LSB first


@dataclasses.dataclass
class MlpCells:
    hidden: list        # [MacNeuronCell], ReLU outputs feed the next layer
    outputs: list       # [MacNeuronCell], (pos, neg) pairs feed the argmax
    shift: int          # static right shift applied after every ReLU


def _mac_rows(nb: NetlistBuilder, in_vecs, weights):
    """Split a neuron's MAC terms into (positive, negative) shifted-copy rows.

    Each set bit s of |w| contributes the input vector shifted left by s
    (free wire: s leading CONST0s); the sign of w routes the row to the
    positive or negative accumulator."""
    pos, neg = [], []
    for vec, w in zip(in_vecs, weights):
        w = int(w)
        if w == 0:
            continue
        dst = pos if w > 0 else neg
        mag, s = abs(w), 0
        while mag:
            if mag & 1:
                dst.append([nb.zero] * s + list(vec))
            mag >>= 1
            s += 1
    return pos, neg


def build_mac_neuron(nb: NetlistBuilder, in_vecs, weights, *,
                     relu: bool, shift: int = 0) -> MacNeuronCell:
    """Lower one integer-weight neuron into the shared builder."""
    pos_rows, neg_rows = _mac_rows(nb, in_vecs, weights)
    pos = nb.sum_vecs(pos_rows)
    neg = nb.sum_vecs(neg_rows)
    out = []
    if relu:
        # ReLU: pos > neg ? pos - neg : 0; `sub` wraps when pos < neg but the
        # mux masks that case. The static right shift is free wire (bit drop).
        sel = nb.gt(pos, neg)
        diff = nb.mux_vec(sel, nb.sub(pos, neg), [nb.zero] * max(len(pos), len(neg)))
        out = diff[shift:] if shift < len(diff) else [nb.zero]
    return MacNeuronCell(list(int(w) for w in weights), relu, pos, neg, out)


def build_mlp_circuit(w1, w2, shift: int, n_classes: int) -> Circuit:
    """Integer-weight MLP (one hidden ReLU layer) -> verified netlist.

    `w1` (F, H) and `w2` (H, C) are EFFECTIVE signed integer weight codes
    (post-snap, rescaled to the master grid); `shift` is the static right
    shift applied to every ReLU output. Inputs are the 8-bit master codes.
    The argmax chain keeps first-max tie semantics (matching `jnp.argmax`)
    by replacing the incumbent only on strict greater-than, scanning classes
    in ascending order. Bit-exact against the tensor forward pass because
    both sides compute exact integer arithmetic (DESIGN.md §15).
    """
    w1 = np.asarray(w1)
    w2 = np.asarray(w2)
    n_features, n_hidden = w1.shape
    if w2.shape != (n_hidden, n_classes):
        raise ValueError(f"w2 shape {w2.shape} != ({n_hidden}, {n_classes})")
    nb = NetlistBuilder()
    in_vecs = [[nb.input_bit(f, i) for i in range(MASTER_BITS)]
               for f in range(n_features)]
    hidden = [build_mac_neuron(nb, in_vecs, w1[:, j], relu=True, shift=shift)
              for j in range(n_hidden)]
    h_vecs = [cell.out for cell in hidden]
    outputs = [build_mac_neuron(nb, h_vecs, w2[:, c], relu=False)
               for c in range(n_classes)]

    n_bits = class_bits(n_classes)
    best_pos, best_neg = outputs[0].pos, outputs[0].neg
    best_idx = nb.const_vec(0, n_bits)
    for c in range(1, n_classes):
        # s_c > s_best  <=>  pos_c + neg_best > pos_best + neg_c  (unsigned)
        sel = nb.gt(nb.add(outputs[c].pos, best_neg),
                    nb.add(best_pos, outputs[c].neg))
        best_pos = nb.mux_vec(sel, outputs[c].pos, best_pos)
        best_neg = nb.mux_vec(sel, outputs[c].neg, best_neg)
        best_idx = nb.mux_vec(sel, nb.const_vec(c, n_bits), best_idx)
    return Circuit(
        op=np.asarray(nb.op, np.int8),
        a=np.asarray(nb.a, np.int32),
        b=np.asarray(nb.b, np.int32),
        out_bits=tuple(best_idx[:n_bits]),
        trees=[MlpCells(hidden, outputs, int(shift))],
        n_classes=int(n_classes),
    )


# ---------------------------------------------------------------------------
# batched simulation — the hardware oracle
# ---------------------------------------------------------------------------

def levelize(circuit: Circuit) -> np.ndarray:
    """(G,) int32 logic level per gate (0 = inputs/constants).

    Gate ids are topologically ordered by construction, so one linear pass
    suffices. Shared by `simulate` and the fault-injection simulator
    (`core.faults`, DESIGN.md §17), which applies stuck-at overrides as
    per-level masks on the same schedule.
    """
    op, a, b = circuit.op, circuit.a, circuit.b
    level = np.zeros(circuit.n_gates, np.int32)
    for i in np.flatnonzero(op >= NOT):
        la = level[a[i]]
        lb = level[b[i]] if op[i] != NOT else 0
        level[i] = max(la, lb) + 1
    return level


def simulate(circuit: Circuit, x8) -> jnp.ndarray:
    """(B,) predicted class over (B, F) int master codes.

    One vectorized pass, no `lax.scan`: gates are grouped by logic level
    (operands always precede gates, so one linear pass levelizes), and each
    level is a single masked gather + boolean op over all its gates at once.
    Bit-exact against `search.problem.predict_votes` by construction —
    asserted per pareto point by the engine's `--verify-rtl` path.
    """
    op, a, b = circuit.op, circuit.a, circuit.b
    g = circuit.n_gates
    logic = op >= NOT
    level = levelize(circuit)

    x8 = jnp.asarray(x8, jnp.int32)
    n_b = x8.shape[0]
    vals = jnp.zeros((n_b, g), jnp.bool_)

    base = np.flatnonzero(level == 0)
    feat = np.maximum(a[base], 0)
    bit = np.maximum(b[base], 0)
    in_vals = ((x8[:, feat] >> bit[None, :]) & 1).astype(jnp.bool_)
    base_ops = op[base][None, :]
    base_vals = jnp.where(base_ops == INPUT, in_vals, base_ops == CONST1)
    vals = vals.at[:, base].set(base_vals)

    for lvl in range(1, int(level.max()) + 1 if logic.any() else 1):
        idx = np.flatnonzero(level == lvl)
        if idx.size == 0:
            continue
        av = vals[:, a[idx]]
        bv = vals[:, np.maximum(b[idx], 0)]
        ops = op[idx][None, :]
        out = jnp.where(
            ops == NOT, ~av,
            jnp.where(ops == AND, av & bv,
                      jnp.where(ops == OR, av | bv, av ^ bv)))
        vals = vals.at[:, idx].set(out)

    cls = jnp.zeros((n_b,), jnp.int32)
    for i, w in enumerate(circuit.out_bits):
        cls = cls | (vals[:, w].astype(jnp.int32) << i)
    return cls


# ---------------------------------------------------------------------------
# measured area — the estimated-vs-actual artifact
# ---------------------------------------------------------------------------

def gate_counts(circuit: Circuit) -> dict:
    """Logic-gate inventory after CSE/constant propagation."""
    ops, counts = np.unique(circuit.op, return_counts=True)
    by_name = {OP_NAMES[o]: int(c) for o, c in zip(ops, counts)}
    return {name: by_name.get(name, 0) for name in ("and", "or", "not", "xor")}


def netlist_area_mm2(circuit: Circuit) -> float:
    """Synthesized-netlist area: every gate priced, nothing estimated.

    This is the framework's "actual" oracle standing in for the paper's DC
    measurements; compare against the GA's additive-LUT estimate
    (`search.problem.chromosome_area_mm2`) for the Fig. 5 gap."""
    c = gate_counts(circuit)
    return area_mod.gate_area_mm2(n_and=c["and"], n_or=c["or"],
                                  n_not=c["not"], n_xor=c["xor"])
