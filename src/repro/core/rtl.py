"""Bespoke RTL (Verilog) emission for exact/approximate Decision Trees.

Mirrors the paper's flow: the tree structure is parsed into a fully-parallel
netlist — one hard-wired comparator per internal node, a path-AND per leaf and
a one-hot class encoder — ready for synthesis with a printed-technology PDK.
"""
from __future__ import annotations

import numpy as np

from repro.core.tree import ParallelTree


def _comparator_expr(x_name: str, bits: int, t_int: int) -> str:
    if t_int >= (1 << bits) - 1:
        return "1'b0"  # X > max is constant false
    return f"({x_name}[7:{8 - bits}] > {bits}'d{t_int})"


def emit_verilog(
    pt: ParallelTree,
    bits: np.ndarray,
    t_int: np.ndarray,
    module_name: str = "bespoke_dtree",
) -> str:
    """Emit a bespoke Verilog module for the (approximate) tree.

    bits/t_int: per-comparator precision and substituted integer threshold.
    Inputs are the 8-bit master codes of each used feature; comparators slice
    their top `bits` bits (truncation = right shift, matching core.quant).
    """
    n_cls_bits = max(1, int(np.ceil(np.log2(max(pt.n_classes, 2)))))
    used_features = sorted(set(int(f) for f in pt.feature))
    lines = [
        f"// Auto-generated bespoke approximate decision tree",
        f"// comparators={pt.n_comparators} leaves={pt.n_leaves} classes={pt.n_classes}",
        f"module {module_name} (",
    ]
    lines += [f"    input  wire [7:0] x{f}," for f in used_features]
    lines += [f"    output wire [{n_cls_bits - 1}:0] class_out", ");"]

    # comparator array (all fire in parallel — the bespoke circuit dataflow)
    for c in range(pt.n_comparators):
        f = int(pt.feature[c])
        expr = _comparator_expr(f"x{f}", int(bits[c]), int(t_int[c]))
        lines.append(f"  wire d{c} = {expr};")

    # per-leaf path AND
    leaf_terms = []
    for l in range(pt.n_leaves):
        lits = []
        for c in range(pt.n_comparators):
            v = int(pt.path[l, c])
            if v == 1:
                lits.append(f"d{c}")
            elif v == -1:
                lits.append(f"~d{c}")
        leaf_terms.append(" & ".join(lits) if lits else "1'b1")
        lines.append(f"  wire leaf{l} = {leaf_terms[-1]};")

    # one-hot class encoder: OR of leaves per class bit
    for b in range(n_cls_bits):
        ors = [
            f"leaf{l}"
            for l in range(pt.n_leaves)
            if (int(pt.leaf_class[l]) >> b) & 1
        ]
        rhs = " | ".join(ors) if ors else "1'b0"
        lines.append(f"  assign class_out[{b}] = {rhs};")

    lines.append("endmodule")
    return "\n".join(lines) + "\n"
