"""Bespoke RTL (Verilog) emission for exact/approximate trees AND forests.

Mirrors the paper's flow: the tree structure is lowered to the gate-level
netlist IR (`core.netlist`, DESIGN.md §10) — one hard-wired comparator per
internal node, a path-AND per leaf, a one-hot class encoder — and the Verilog
below is printed from those cells, ready for synthesis with a
printed-technology PDK. A forest becomes per-tree modules plus the
majority-vote adder tree + argmax chain (§2's vote matmul in hardware). The
same netlist simulates batched in jnp (`netlist.simulate`), so every emitted
module has a bit-exact software oracle (`--verify-rtl`).
"""
from __future__ import annotations

import numpy as np

from repro.core import netlist as nl_mod
from repro.core.tree import ParallelTree


def _comparator_expr(x_name: str, bits: int, t_int: int) -> str:
    if t_int >= (1 << bits) - 1:
        return "1'b0"  # X > max is constant false
    return f"({x_name}[7:{8 - bits}] > {bits}'d{t_int})"


def _tree_body_lines(cells: nl_mod.TreeCells) -> list[str]:
    """Comparator + path-AND wires, printed from the netlist cells."""
    lines = []
    for i, comp in enumerate(cells.comparators):
        expr = _comparator_expr(f"x{comp.feature}", comp.bits, comp.t_int)
        lines.append(f"  wire d{i} = {expr};")
    for l, leaf in enumerate(cells.leaves):
        lits = [f"d{c}" if pos else f"~d{c}" for c, pos in leaf.literals]
        expr = " & ".join(lits) if lits else "1'b1"
        lines.append(f"  wire leaf{l} = {expr};")
    return lines


def _class_or_expr(cells: nl_mod.TreeCells, pred) -> str:
    ors = [f"leaf{l}" for l, leaf in enumerate(cells.leaves)
           if pred(leaf.leaf_class)]
    return " | ".join(ors) if ors else "1'b0"


def emit_verilog(
    pt: ParallelTree,
    bits: np.ndarray,
    t_int: np.ndarray,
    module_name: str = "bespoke_dtree",
    trunc=None,
) -> str:
    """Emit a bespoke Verilog module for one (approximate) tree.

    bits/t_int: per-comparator precision and SUBSTITUTED integer threshold;
    trunc (optional) per-comparator LSB-truncation depths (DESIGN.md §16).
    Inputs are the 8-bit master codes of each used feature; comparators slice
    their top `bits - trunc` bits (truncation = right shift, matching
    core.quant) and compare against `t_int >> trunc`.
    """
    nb = nl_mod.NetlistBuilder()
    cells = nl_mod.build_tree_cells(nb, pt, bits, t_int, pt.n_classes,
                                    trunc=trunc)
    n_cls_bits = nl_mod.class_bits(pt.n_classes)
    used_features = sorted(set(int(f) for f in pt.feature))
    lines = [
        f"// Auto-generated bespoke approximate decision tree",
        f"// comparators={pt.n_comparators} leaves={pt.n_leaves} classes={pt.n_classes}",
        f"module {module_name} (",
    ]
    lines += [f"    input  wire [7:0] x{f}," for f in used_features]
    lines += [f"    output wire [{n_cls_bits - 1}:0] class_out", ");"]
    lines += _tree_body_lines(cells)
    # one-hot class encoder: OR of leaves per class bit
    for b in range(n_cls_bits):
        rhs = _class_or_expr(cells, lambda c: (c >> b) & 1)
        lines.append(f"  assign class_out[{b}] = {rhs};")
    lines.append("endmodule")
    return "\n".join(lines) + "\n"


def emit_forest_verilog(ptrees, bits, t_int, n_classes: int | None = None,
                        module_name: str = "bespoke_forest", trunc=None,
                        vote_adder: str = "exact") -> str:
    """Emit a bespoke forest: per-tree vote modules + the majority-vote top.

    bits/t_int are CONCATENATED per-comparator arrays across the K trees
    (the joint-chromosome layout of `SearchProblem`); trunc optionally
    truncates comparator LSB stages (DESIGN.md §16). Each tree module emits
    its one-hot class vote (OR of its class's leaves); the top module scores
    votes per class — `vote_adder="exact"` sums them with an adder tree
    (§2's vote matmul in hardware), `"approx"` saturates each class to the
    1-bit OR of its votes — and selects the argmax with first-max
    tie-breaking, exactly matching `predict_votes` / the fused Pallas
    kernel (ties -> lowest class index).
    """
    if vote_adder not in ("exact", "approx"):
        raise ValueError(f"unknown vote_adder {vote_adder!r}")
    if isinstance(ptrees, ParallelTree):
        ptrees = [ptrees]
    if n_classes is None:
        n_classes = max(pt.n_classes for pt in ptrees)
    bits = np.asarray(bits)
    t_int = np.asarray(t_int)
    trunc = (np.zeros_like(bits) if trunc is None else np.asarray(trunc))
    n_trees = len(ptrees)
    n_cls_bits = nl_mod.class_bits(n_classes)
    approx_vote = vote_adder == "approx"
    # exact counts reach K; the approximate OR-tree saturates at 1 bit
    cnt_bits = 1 if approx_vote else max(1, n_trees.bit_length())

    nb = nl_mod.NetlistBuilder()
    all_cells, off = [], 0
    for pt in ptrees:
        n = pt.n_comparators
        all_cells.append(nl_mod.build_tree_cells(
            nb, pt, bits[off:off + n], t_int[off:off + n], n_classes,
            trunc=trunc[off:off + n]))
        off += n

    lines = [
        f"// Auto-generated bespoke approximate random forest",
        f"// trees={n_trees} comparators={off} classes={n_classes}",
    ]
    # per-tree vote modules
    for k, (pt, cells) in enumerate(zip(ptrees, all_cells)):
        used = sorted(set(int(f) for f in pt.feature))
        lines.append(f"module {module_name}_tree{k} (")
        lines += [f"    input  wire [7:0] x{f}," for f in used]
        lines += [f"    output wire [{n_classes - 1}:0] vote", ");"]
        lines += _tree_body_lines(cells)
        for c in range(n_classes):
            rhs = _class_or_expr(cells, lambda lc: lc == c)
            lines.append(f"  assign vote[{c}] = {rhs};")
        lines.append("endmodule")
        lines.append("")

    # top module: instantiate trees, adder-tree vote counts, argmax chain
    used_all = sorted({int(f) for pt in ptrees for f in pt.feature})
    lines.append(f"module {module_name} (")
    lines += [f"    input  wire [7:0] x{f}," for f in used_all]
    lines += [f"    output wire [{n_cls_bits - 1}:0] class_out", ");"]
    for k, pt in enumerate(ptrees):
        used = sorted(set(int(f) for f in pt.feature))
        ports = ", ".join([f".x{f}(x{f})" for f in used] + [f".vote(vote{k})"])
        lines.append(f"  wire [{n_classes - 1}:0] vote{k};")
        lines.append(f"  {module_name}_tree{k} t{k} ({ports});")
    if approx_vote:
        lines.append("  // approximate vote adder: saturating OR-tree "
                     "(DESIGN.md §16)")
        for c in range(n_classes):
            total = " | ".join(f"vote{k}[{c}]" for k in range(n_trees))
            lines.append(f"  wire [{cnt_bits - 1}:0] cnt{c} = {total};")
    else:
        lines.append("  // majority-vote adder tree "
                     "(the vote matmul in hardware)")
        for c in range(n_classes):
            total = " + ".join(f"vote{k}[{c}]" for k in range(n_trees))
            lines.append(f"  wire [{cnt_bits - 1}:0] cnt{c} = {total};")
    lines.append("  // argmax chain, ties -> lowest class index")
    lines.append(f"  wire [{cnt_bits - 1}:0] best0 = cnt0;")
    lines.append(f"  wire [{n_cls_bits - 1}:0] idx0 = {n_cls_bits}'d0;")
    for c in range(1, n_classes):
        lines.append(f"  wire sel{c} = (cnt{c} > best{c - 1});")
        lines.append(f"  wire [{cnt_bits - 1}:0] best{c} = "
                     f"sel{c} ? cnt{c} : best{c - 1};")
        lines.append(f"  wire [{n_cls_bits - 1}:0] idx{c} = "
                     f"sel{c} ? {n_cls_bits}'d{c} : idx{c - 1};")
    lines.append(f"  assign class_out = idx{n_classes - 1};")
    lines.append("endmodule")
    return "\n".join(lines) + "\n"


def emit_circuit_verilog(circuit: nl_mod.Circuit,
                         module_name: str = "bespoke_circuit") -> str:
    """Emit any finished gate-level `netlist.Circuit` as structural Verilog.

    The generic lowering for families whose netlists are built gate-by-gate
    rather than from tree cells — e.g. the printed-MLP MAC/activation
    circuits (`netlist.build_mlp_circuit`, DESIGN.md §15). One wire per
    hash-consed gate, inputs as the 8-bit master-code ports the gate array
    references, outputs the class-index bits LSB first. `netlist.simulate`
    is the bit-exact software oracle for the emitted module.
    """
    op = np.asarray(circuit.op)
    a = np.asarray(circuit.a)
    b = np.asarray(circuit.b)
    features = sorted({int(a[g]) for g in range(op.shape[0])
                       if op[g] == nl_mod.INPUT})
    n_out = len(circuit.out_bits)
    lines = [
        f"// Auto-generated bespoke gate-level circuit",
        f"// gates={int(op.shape[0])} classes={circuit.n_classes}",
        f"module {module_name} (",
    ]
    lines += [f"    input  wire [7:0] x{f}," for f in features]
    lines += [f"    output wire [{max(n_out - 1, 0)}:0] class_out", ");"]
    exprs = {0: "1'b0", 1: "1'b1"}  # CONST0/CONST1 are always gates 0 and 1
    for g in range(op.shape[0]):
        o = int(op[g])
        if o in (nl_mod.CONST0, nl_mod.CONST1):
            continue
        if o == nl_mod.INPUT:
            rhs = f"x{int(a[g])}[{int(b[g])}]"
        elif o == nl_mod.NOT:
            rhs = f"~{exprs[int(a[g])]}"
        else:
            sym = {nl_mod.AND: "&", nl_mod.OR: "|", nl_mod.XOR: "^"}[o]
            rhs = f"{exprs[int(a[g])]} {sym} {exprs[int(b[g])]}"
        lines.append(f"  wire g{g} = {rhs};")
        exprs[g] = f"g{g}"
    for i, w in enumerate(circuit.out_bits):
        lines.append(f"  assign class_out[{i}] = {exprs[int(w)]};")
    lines.append("endmodule")
    return "\n".join(lines) + "\n"


def emit_design(ptrees, bits, t_int, n_classes: int | None = None,
                module_name: str | None = None, trunc=None,
                vote_adder: str = "exact") -> str:
    """One entry point: a single tree emits `emit_verilog`, K > 1 the forest
    hierarchy. `bits`/`t_int` are concatenated per-comparator arrays;
    `trunc`/`vote_adder` select the approximate cells (DESIGN.md §16 — the
    vote mode is inert for a single tree, which has no vote stage)."""
    if isinstance(ptrees, ParallelTree):
        ptrees = [ptrees]
    if len(ptrees) == 1:
        return emit_verilog(ptrees[0], bits, t_int,
                            module_name=module_name or "bespoke_dtree",
                            trunc=trunc)
    return emit_forest_verilog(ptrees, bits, t_int, n_classes=n_classes,
                               module_name=module_name or "bespoke_forest",
                               trunc=trunc, vote_adder=vote_adder)
