"""Bespoke-comparator area model + Area LUT (paper Fig. 4) + power model.

The container has no Synopsys DC / EGT PDK, so the LUT is produced by an
exact gate count of the constant-propagated comparator netlist, calibrated to
the paper's published magnitudes (Table I / Fig. 4). See DESIGN.md §4.

Hard-wired unsigned greater-than:  X > t  ==  X >= u with u = t + 1.
Scanning u from the LSB, with g initially "true":
  - bits below the lowest set bit of u are free (g stays true),
  - the lowest set bit j gives g = X_j (free),
  - every higher bit adds exactly one 2-input gate:
      u_i = 1 -> g = X_i AND g      (AND2)
      u_i = 0 -> g = X_i OR  g      (OR2)
  - u = 2^p (t = 2^p - 1) is constant-false: zero gates.

So gates(t, p) = p - 1 - tz(t + 1)   (tz = count of trailing zeros), split
into ANDs/ORs by the bit pattern — non-linear in t with valleys at
t = 2^k - 1 and a sawtooth over odd/even t, matching the character of the
paper's Fig. 4. No inverters are ever needed for a constant comparison in
this form.

EGT calibration (printed gates are *large*):
  AREA_AND2 / AREA_OR2 are per-gate areas in mm^2; NODE/LEAF overheads model
  the leaf-decode + class-mux logic the paper synthesizes around the
  comparators. POWER_PER_MM2 is the slope that reproduces every row of the
  paper's Table I within ~5% (7.55/162.50 = 0.0465 ... 25.0/574.46 = 0.0435).
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.quant import MAX_BITS, MIN_BITS

# --- EGT PDK calibration constants (see DESIGN.md §4 and benchmarks) --------
# Fitted against paper Table I with the unique-comparator (CSE) model:
# printed EGT 2-input gates are ~0.56 mm^2; per-node overheads are tiny once
# sharing is accounted for (benchmarks/paper_tables.py::calibration).
AREA_AND2_MM2 = 0.55     # printed EGT 2-input gate
AREA_OR2_MM2 = 0.57
AREA_NOT_MM2 = 0.28      # inverter: ~half a 2-input EGT gate
AREA_XOR2_MM2 = 0.83     # 2-input XOR: ~1.5x AND2 (vote adders, DESIGN.md §10)

# Every gate area above is an integer multiple of this quantum, so a
# comparator area is an exact integer number of quanta. The sweep engine
# (DESIGN.md §11) scores area by summing *integer* quanta in f32 — exact for
# any reduction order/tiling as long as the total stays < 2^24 quanta
# (167 m^2 of circuit) — and scales once at the end, which is what makes the
# vmapped multi-problem fitness bit-identical to the serial loop.
AREA_QUANTUM_MM2 = 0.01
_AND2_UNITS = round(AREA_AND2_MM2 / AREA_QUANTUM_MM2)
_OR2_UNITS = round(AREA_OR2_MM2 / AREA_QUANTUM_MM2)
assert abs(_AND2_UNITS * AREA_QUANTUM_MM2 - AREA_AND2_MM2) < 1e-12
assert abs(_OR2_UNITS * AREA_QUANTUM_MM2 - AREA_OR2_MM2) < 1e-12
NODE_OVERHEAD_MM2 = 0.02  # per internal node: routing + decision buffering
LEAF_OVERHEAD_MM2 = 0.04  # per leaf: path-AND + class mux contribution
POWER_PER_MM2_MW = 0.0455  # paper Table I slope (mW per mm^2)
DELAY_BASE_MS = 19.2       # paper Table I affine fit (reported for completeness)
DELAY_PER_COMP_MS = 0.11


def comparator_gate_counts(t: int, p: int) -> tuple[int, int]:
    """(n_and2, n_or2) for hard-wired ``X > t`` with p-bit unsigned X."""
    u = t + 1
    if u >= (1 << p):
        return 0, 0
    tz = (u & -u).bit_length() - 1  # trailing zeros
    n_and = bin(u >> (tz + 1)).count("1")            # set bits above lowest
    n_or = (p - 1 - tz) - n_and                      # clear bits above lowest
    return n_and, n_or


def comparator_area_mm2(t: int, p: int) -> float:
    n_and, n_or = comparator_gate_counts(t, p)
    return n_and * AREA_AND2_MM2 + n_or * AREA_OR2_MM2


def trunc_comparator_gate_counts(t: int, p: int, k: int) -> tuple[int, int]:
    """(n_and2, n_or2) for a k-LSB-truncated p-bit comparator (DESIGN.md §16).

    Dropping the k lowest stages of the hard-wired ``X > t`` chain leaves
    exactly the exact comparator of width p - k against threshold t >> k —
    so truncated cells are priced (and lowered) with the same primitives.
    Width p - k <= 0 degenerates to constant false: zero gates.
    """
    if k >= p:
        return 0, 0
    return comparator_gate_counts(t >> k, p - k)


def trunc_comparator_area_mm2(t: int, p: int, k: int) -> float:
    n_and, n_or = trunc_comparator_gate_counts(t, p, k)
    return n_and * AREA_AND2_MM2 + n_or * AREA_OR2_MM2


def build_area_lut() -> tuple[np.ndarray, np.ndarray]:
    """Exhaustive LUT over p in [0, MAX_BITS], t in [0, 2^p).

    Returns (lut, offsets):
      lut: float32[sum 2^p] of comparator areas (mm^2)
      offsets: int32[MAX_BITS+1], LUT row start per precision; entry for
               precision p is lut[offsets[p] + t].

    Rows below MIN_BITS exist because LSB truncation (DESIGN.md §16) shrinks
    a comparator's *effective* width down to MIN_BITS - MAX_TRUNC (= 0, the
    constant-false comparator); those rows are all-zero (a 0/1-bit unsigned
    greater-than needs no gates) but must occupy distinct offsets so
    `offsets[p_eff] + t_eff` never aliases a wider row.
    """
    offsets = np.zeros(MAX_BITS + 1, dtype=np.int32)
    chunks = []
    pos = 0
    for p in range(0, MAX_BITS + 1):
        offsets[p] = pos
        row = np.array(
            [comparator_area_mm2(t, p) for t in range(1 << p)], dtype=np.float32
        )
        chunks.append(row)
        pos += 1 << p
    return np.concatenate(chunks).astype(np.float32), offsets


def comparator_area_units(t: int, p: int) -> int:
    """Comparator area as an exact integer count of AREA_QUANTUM_MM2 quanta."""
    n_and, n_or = comparator_gate_counts(t, p)
    return n_and * _AND2_UNITS + n_or * _OR2_UNITS


def build_area_unit_lut() -> tuple[np.ndarray, np.ndarray]:
    """Integer-quanta twin of `build_area_lut` (same indexing scheme).

    Entries are small integers stored as f32 (exactly representable), so a
    masked/padded population sum of LUT rows is bit-identical under any
    reduction order — the property the vmapped sweep fitness relies on
    (DESIGN.md §11). `lut_units * AREA_QUANTUM_MM2` recovers mm^2.
    """
    offsets = np.zeros(MAX_BITS + 1, dtype=np.int32)
    chunks = []
    pos = 0
    for p in range(0, MAX_BITS + 1):
        offsets[p] = pos
        row = np.array([comparator_area_units(t, p) for t in range(1 << p)],
                       dtype=np.float32)
        chunks.append(row)
        pos += 1 << p
    return np.concatenate(chunks), offsets


# --- forest vote-adder cells (DESIGN.md §16) --------------------------------
# The vote stage of a K-tree forest is priced from the SAME netlist the
# hardware lowers to: an isolated vote-stage harness (popcount + argmax chain
# for the exact adder, saturating OR-tree + 1-bit argmax for the approximate
# one) is built once per (n_trees, n_classes, mode) and its gate inventory
# converted to exact integer quanta. Deferred import breaks the
# netlist -> area module cycle; lru_cache makes repeat pricing free.


@functools.lru_cache(maxsize=None)
def vote_adder_units(n_trees: int, n_classes: int, approx: bool) -> int:
    """Vote-adder area as exact integer AREA_QUANTUM_MM2 quanta.

    Zero for single-tree designs (K = 1 encodes the winning class directly,
    no adder exists in either mode — the vote gene is inert there)."""
    if n_trees <= 1:
        return 0
    from repro.core import netlist
    counts = netlist.vote_adder_gate_counts(n_trees, n_classes, approx=approx)
    units = gate_area_mm2(*counts) / AREA_QUANTUM_MM2
    iunits = round(units)
    assert abs(iunits - units) < 1e-6
    return iunits


def vote_adder_area_mm2(n_trees: int, n_classes: int, approx: bool) -> float:
    return vote_adder_units(n_trees, n_classes, approx) * AREA_QUANTUM_MM2


# --- printed-MLP MAC / activation cells (DESIGN.md §15) ---------------------
# A MAC term is lowered as shifted-copy rows through ripple full adders (the
# §10 `full_add` cell: 2 XOR2 + 2 AND2 + 1 OR2); a negative weight costs one
# extra adder row (two's-complement add of the inverted operand). The
# activation cell (ReLU / argmax compare leg) is priced per accumulator bit:
# one compare stage (XOR2 + 2 AND2 + OR2 + NOT) per bit. All constants are
# integer multiples of AREA_QUANTUM_MM2, so MLP areas sum in exact integer
# quanta exactly like comparator areas — the property the vmapped sweep
# fitness relies on (DESIGN.md §11).
AREA_FA_MM2 = 2 * AREA_XOR2_MM2 + 2 * AREA_AND2_MM2 + AREA_OR2_MM2
AREA_ACT_BIT_MM2 = AREA_XOR2_MM2 + 2 * AREA_AND2_MM2 + AREA_OR2_MM2 + AREA_NOT_MM2
_FA_UNITS = round(AREA_FA_MM2 / AREA_QUANTUM_MM2)
_ACT_BIT_UNITS = round(AREA_ACT_BIT_MM2 / AREA_QUANTUM_MM2)
assert abs(_FA_UNITS * AREA_QUANTUM_MM2 - AREA_FA_MM2) < 1e-9
assert abs(_ACT_BIT_UNITS * AREA_QUANTUM_MM2 - AREA_ACT_BIT_MM2) < 1e-9


def mac_area_units(code: int, in_bits: int) -> int:
    """One integer-weight MAC term as exact AREA_QUANTUM_MM2 quanta.

    `code` is the effective signed weight; each set bit of |code| is one
    shifted-copy adder row of `in_bits` full adders, and a negative weight
    adds one subtractor row. A zero weight is free wire."""
    c = int(code)
    if c == 0:
        return 0
    rows = bin(abs(c)).count("1") + (1 if c < 0 else 0)
    return rows * int(in_bits) * _FA_UNITS


def mac_area_mm2(code: int, in_bits: int) -> float:
    return mac_area_units(code, in_bits) * AREA_QUANTUM_MM2


def act_area_units(acc_bits: int) -> int:
    """Activation cell (ReLU zero-mux or argmax compare leg) in quanta."""
    return int(acc_bits) * _ACT_BIT_UNITS


def act_area_mm2(acc_bits: int) -> float:
    return act_area_units(acc_bits) * AREA_QUANTUM_MM2


def mlp_neuron_area_units(codes, in_bits: int, acc_bits: int) -> int:
    """Area of one printed-MLP neuron: its MAC terms + one activation cell."""
    import numpy as np
    codes = np.asarray(codes).ravel()
    return (sum(mac_area_units(int(c), in_bits) for c in codes.tolist())
            + act_area_units(acc_bits))


def gate_area_mm2(n_and: int = 0, n_or: int = 0, n_not: int = 0,
                  n_xor: int = 0) -> float:
    """Area of an explicit gate inventory (the netlist oracle, DESIGN.md §10).

    Unlike the additive LUT estimate, this prices EVERY gate the circuit
    actually contains — comparators after CSE, path-AND inverters, and the
    forest vote adder/argmax logic the LUT models only as per-node/leaf
    overheads."""
    return (n_and * AREA_AND2_MM2 + n_or * AREA_OR2_MM2
            + n_not * AREA_NOT_MM2 + n_xor * AREA_XOR2_MM2)


def tree_overhead_mm2(n_comparators: int, n_leaves: int) -> float:
    return n_comparators * NODE_OVERHEAD_MM2 + n_leaves * LEAF_OVERHEAD_MM2


def tree_area_mm2(features, t_ints, bits, n_leaves: int,
                  dedup: bool = False) -> float:
    """Total bespoke-tree area.

    dedup=False: paper-faithful additive LUT sum (the GA's area estimate).
    dedup=True : synthesis-accurate model — identical (feature, threshold,
      precision) comparators are shared by CSE, as Design Compiler does for
      bespoke circuits. This is this framework's "actual" oracle standing in
      for the paper's DC measurements (the paper's own estimated-vs-actual
      gap in Fig. 5 — HAR/Mammographic/WhiteWine — is exactly a sharing gap).
    """
    import numpy as np
    features = np.asarray(features)
    t_ints = np.asarray(t_ints)
    bits = np.asarray(bits)
    if dedup:
        seen = {}
        for f, t, p in zip(features.tolist(), t_ints.tolist(), bits.tolist()):
            seen[(f, t, p)] = comparator_area_mm2(int(t), int(p))
        comp_area = sum(seen.values())
    else:
        comp_area = sum(comparator_area_mm2(int(t), int(p))
                        for t, p in zip(t_ints.tolist(), bits.tolist()))
    return comp_area + tree_overhead_mm2(len(features), n_leaves)


def power_mw(area_mm2: float) -> float:
    return POWER_PER_MM2_MW * area_mm2


def delay_ms(n_comparators: int) -> float:
    return DELAY_BASE_MS + DELAY_PER_COMP_MS * n_comparators
