"""Flattened decision trees + the TPU-native *parallel comparator-array* form.

The paper's bespoke circuit is fully parallel: every comparator evaluates
simultaneously and leaf-decode logic selects the class. We mirror exactly that
dataflow so DT inference lands on the MXU instead of pointer-chasing:

  decisions D[b, n] = (x_int[b, feat[n]] > t_int[n])          (comparator array)
  score[b, l]      = D[b] . P[l] + n_neg[l]                   (path matmul)
  leaf[b]          = argmax_l (score[b, l] - path_len[l])     (decode; max == 0)

P[l, n] = +1 if leaf l's path requires decision n true (go right), -1 if it
requires it false, 0 if node n is not on the path. score == path_len holds for
exactly one leaf. This is the reference (pure-jnp) implementation; the Pallas
kernel in repro.kernels.tree_infer computes the same fused form.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.train import TreeArrays


@dataclasses.dataclass
class ParallelTree:
    """Comparator-array form. N comparators (internal nodes), L leaves."""

    feature: np.ndarray     # int32[N]  feature index per comparator
    threshold: np.ndarray   # float32[N] trained float threshold in (0,1)
    path: np.ndarray        # int8[L, N] in {-1, 0, +1}
    path_len: np.ndarray    # int32[L]  number of nonzeros per row
    n_neg: np.ndarray       # int32[L]  number of -1 per row
    leaf_class: np.ndarray  # int32[L]
    n_classes: int

    @property
    def n_comparators(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_leaves(self) -> int:
        return int(self.leaf_class.shape[0])


def to_parallel(tree: TreeArrays) -> ParallelTree:
    """Flatten a TreeArrays into the comparator-array + path-matrix form."""
    internal = np.flatnonzero(tree.feature >= 0)
    leaves = np.flatnonzero(tree.feature < 0)
    comp_of_node = {int(n): i for i, n in enumerate(internal)}
    n_comp, n_leaf = len(internal), len(leaves)

    path = np.zeros((n_leaf, max(n_comp, 1)), dtype=np.int8)
    # DFS carrying the (comparator, direction) prefix
    stack = [(0, [])]
    leaf_rows = {}
    while stack:
        node, prefix = stack.pop()
        if tree.feature[node] < 0:
            leaf_rows[node] = prefix
            continue
        c = comp_of_node[node]
        stack.append((int(tree.left[node]), prefix + [(c, -1)]))
        stack.append((int(tree.right[node]), prefix + [(c, +1)]))
    for row, node in enumerate(leaves):
        for c, d in leaf_rows[int(node)]:
            path[row, c] = d

    pl = (path != 0).sum(axis=1).astype(np.int32)
    nn = (path == -1).sum(axis=1).astype(np.int32)
    return ParallelTree(
        feature=tree.feature[internal].astype(np.int32),
        threshold=tree.threshold[internal].astype(np.float32),
        path=path,
        path_len=pl,
        n_neg=nn,
        leaf_class=tree.leaf_class[leaves].astype(np.int32),
        n_classes=tree.n_classes,
    )


def concatenate_ptrees(ptrees) -> dict:
    """Concatenated comparator/leaf arrays + block-diagonal super-tree path.

    THE single definition of the multi-tree layout (DESIGN.md §7): the
    comparator axis concatenates every tree's comparators, the leaf axis
    every tree's leaves, and `path` is block-diagonal so each leaf row only
    sees its own tree's comparators. Shared by `repro.search.problem` and
    `repro.kernels.ops` so the reference and kernel operand layouts cannot
    diverge. Returns numpy arrays.
    """
    n_total = sum(pt.n_comparators for pt in ptrees)
    l_total = sum(pt.n_leaves for pt in ptrees)
    path = np.zeros((l_total, n_total), np.int8)
    leaf_tree = np.concatenate([
        np.full(pt.n_leaves, k, np.int32) for k, pt in enumerate(ptrees)])
    n_off = l_off = 0
    for pt in ptrees:
        path[l_off:l_off + pt.n_leaves, n_off:n_off + pt.n_comparators] = pt.path
        n_off += pt.n_comparators
        l_off += pt.n_leaves
    return {
        "feature": np.concatenate([pt.feature for pt in ptrees]).astype(np.int32),
        "threshold": np.concatenate(
            [pt.threshold for pt in ptrees]).astype(np.float32),
        "path": path,
        "path_len": np.concatenate(
            [pt.path_len for pt in ptrees]).astype(np.int32),
        "n_neg": np.concatenate([pt.n_neg for pt in ptrees]).astype(np.int32),
        "leaf_class": np.concatenate(
            [pt.leaf_class for pt in ptrees]).astype(np.int32),
        "leaf_tree": leaf_tree,
    }


# ---------------------------------------------------------------------------
# pure-jnp reference predictors (oracles for the Pallas kernel)
# ---------------------------------------------------------------------------

def decisions_quantized(x8, feature, threshold, bits, margin):
    """Comparator array under the dual approximation.

    x8: (B, F) int32 master codes; feature (N,), threshold (N,) float,
    bits (N,) int32 in [2,8], margin (N,) int32 in [-5,5].
    Returns bool (B, N).
    """
    t_int = quant.threshold_to_int(threshold, bits)
    t_sub = quant.substitute(t_int, margin, bits)
    x_gathered = x8[:, feature]                      # (B, N)
    x_p = quant.inputs_at_precision(x_gathered, bits)
    return x_p > t_sub[None, :]


def leaves_from_decisions(decisions, path, path_len):
    """decisions bool (B, N) -> leaf index (B,) via the path matmul."""
    d = decisions.astype(jnp.float32)
    score = d @ path.astype(jnp.float32).T           # (B, L): (+1 hits) - (-1 hits)
    # satisfied leaf: (+1 hits) + (#neg - (-1 hits)) == path_len
    # score + n_neg == path_len  <=>  score - (path_len - n_neg) == 0 (max)
    target = (path_len - (path == -1).sum(axis=1)).astype(jnp.float32)
    return jnp.argmax(score - target[None, :], axis=1)


def predict_quantized(x8, ptree_arrays, bits, margin):
    """Full reference pipeline; ptree_arrays is a dict of jnp arrays."""
    d = decisions_quantized(
        x8,
        ptree_arrays["feature"],
        ptree_arrays["threshold"],
        bits,
        margin,
    )
    leaf = leaves_from_decisions(d, ptree_arrays["path"], ptree_arrays["path_len"])
    return ptree_arrays["leaf_class"][leaf]


def ptree_to_jnp(pt: ParallelTree) -> dict:
    return {
        "feature": jnp.asarray(pt.feature),
        "threshold": jnp.asarray(pt.threshold),
        "path": jnp.asarray(pt.path),
        "path_len": jnp.asarray(pt.path_len),
        "n_neg": jnp.asarray(pt.n_neg),
        "leaf_class": jnp.asarray(pt.leaf_class),
    }


def predict_descent_quantized(x8, tree: TreeArrays, bits_full, margin_full):
    """Oracle #2: sequential descent with quantized comparators (numpy).

    bits_full/margin_full are per-*node* arrays aligned with tree arrays
    (entries at leaf positions ignored). Cross-checks the parallel form.
    """
    x8 = np.asarray(x8)
    n = x8.shape[0]
    node = np.zeros(n, dtype=np.int64)
    bits_full = np.asarray(bits_full)
    margin_full = np.asarray(margin_full)
    for _ in range(tree.n_nodes):
        f = tree.feature[node]
        active = f >= 0
        if not active.any():
            break
        p = bits_full[node]
        t_int = np.floor(tree.threshold[node] * (2.0 ** p)).astype(np.int64)
        t_int = np.clip(t_int, 0, (1 << p) - 1)
        t_sub = np.clip(t_int + margin_full[node], 0, (1 << p) - 1)
        xv = x8[np.arange(n), np.maximum(f, 0)] >> (8 - p)
        go_right = xv > t_sub
        nxt = np.where(go_right, tree.right[node], tree.left[node])
        node = np.where(active, nxt, node)
    return tree.leaf_class[node].astype(np.int32)
