"""Threshold precision-conversion module (paper Fig. 3b; DESIGN.md §3).

Semantics (all integer, derived from the [0,1]-normalized reals):

  master code     x8 = floor(x * 2^8)            in [0, 255]
  input @ p bits  x_p = x8 >> (8 - p)            (truncation)
  thr float  T in (0,1)
  thr fixed  t_p = floor(T * 2^p)                in [0, 2^p - 1]
  substitution    t'_p = clip(t_p + m, 0, 2^p-1) with margin m in [-5, 5]
  comparator      decision = (x_p > t'_p)        -> go right

At p = 8 and m = 0 this reproduces the exact (non-approximate) tree bit-for-
bit, because training thresholds are stored as (t8 + 0.5)/256 (core.train).

The fixed-point value used for accuracy evaluation and the integer used to
index the area LUT are the same code scaled by 2^-p — exactly the paper's
"flexible threshold conversion" between the two representations.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

MASTER_BITS = 8
MIN_BITS = 2
MAX_BITS = 8
MARGIN = 5  # paper §IV: threshold substitution margin m in [-5, +5]
# Cross-layer co-search (DESIGN.md §16): per-comparator LSB truncation depth
# k in [0, MAX_TRUNC]. A k-truncated p-bit comparator ignores its k lowest
# threshold/input bit stages, which is exactly an exact comparator of width
# p - k compared against t' >> k.
MAX_TRUNC = 2
VOTE_ADDER_MODES = ("exact", "approx")


def threshold_to_int(threshold, bits):
    """float T in (0,1) -> fixed-point integer code at ``bits`` precision."""
    b = jnp.asarray(bits, jnp.int32)
    t = jnp.floor(threshold * jnp.exp2(b.astype(jnp.float32))).astype(jnp.int32)
    return jnp.clip(t, 0, jnp.left_shift(1, b) - 1)


def substitute(t_int, margin, bits):
    """Area-driven substitution: move the integer threshold by ``margin``."""
    hi = jnp.left_shift(1, jnp.asarray(bits, jnp.int32)) - 1
    return jnp.clip(t_int + margin, 0, hi)


def inputs_at_precision(x8, bits):
    """Right-shift the master 8-bit code down to per-node precision.

    x8: (..., N) int32 master codes gathered per comparator.
    bits: (N,) int32 per-comparator precision.
    """
    shift = (MASTER_BITS - bits).astype(jnp.int32)
    return jnp.right_shift(x8.astype(jnp.int32), shift)


def decode_genes(genes):
    """Real-coded genes in [0,1]^(2N) -> (bits[N], margin[N]) int32.

    Gene layout follows paper Fig. 3a: per comparator, gene 2k is the
    precision, gene 2k+1 the substitution margin.
    """
    g = jnp.asarray(genes)
    gp, gm = g[..., 0::2], g[..., 1::2]
    span_p = MAX_BITS - MIN_BITS + 1
    bits = MIN_BITS + jnp.clip(jnp.floor(gp * span_p), 0, span_p - 1)
    margin = -MARGIN + jnp.clip(jnp.floor(gm * (2 * MARGIN + 1)), 0, 2 * MARGIN)
    return bits.astype(jnp.int32), margin.astype(jnp.int32)


def exact_genes(n_comparators: int) -> np.ndarray:
    """Chromosome encoding the exact 8-bit, zero-margin design.

    Historical 2-genes-per-comparator layout (paper Fig. 3a). The tree
    search space now also carries approximation genes — use
    `exact_tree_genes` / `decode_tree_genes` for the engine's layout
    (DESIGN.md §16); this pair remains the precision/margin primitive the
    MLP family mirrors at its own ranges.
    """
    g = np.zeros(2 * n_comparators, dtype=np.float32)
    g[0::2] = 0.999  # precision -> 8 bits
    g[1::2] = 0.5    # margin -> 0  (floor(0.5 * 11) = 5 -> m = 0)
    return g


def decode_tree_genes(genes):
    """Cross-layer tree genes [0,1]^(3N+1) -> (bits, margin, trunc, vote).

    Gene layout (DESIGN.md §16): per comparator k, gene 3k is the precision,
    gene 3k+1 the substitution margin (both decoded exactly as
    `decode_genes`), and gene 3k+2 the LSB-truncation depth in
    [0, MAX_TRUNC]. The final gene toggles the forest's vote adder:
    floor(g*2) = 0 selects the exact popcount adder, 1 the approximate
    saturating OR-tree. Returns int32 arrays (bits[N], margin[N], trunc[N])
    and the int32 vote flag (shape = leading batch dims).
    """
    g = jnp.asarray(genes)
    comp = g[..., :-1]
    gp, gm, gt = comp[..., 0::3], comp[..., 1::3], comp[..., 2::3]
    span_p = MAX_BITS - MIN_BITS + 1
    bits = MIN_BITS + jnp.clip(jnp.floor(gp * span_p), 0, span_p - 1)
    margin = -MARGIN + jnp.clip(jnp.floor(gm * (2 * MARGIN + 1)), 0, 2 * MARGIN)
    span_t = MAX_TRUNC + 1
    trunc = jnp.clip(jnp.floor(gt * span_t), 0, span_t - 1)
    vote = jnp.clip(jnp.floor(g[..., -1] * 2), 0, 1)
    return (bits.astype(jnp.int32), margin.astype(jnp.int32),
            trunc.astype(jnp.int32), vote.astype(jnp.int32))


def exact_tree_genes(n_comparators: int) -> np.ndarray:
    """Chromosome for the exact design in the cross-layer layout (§16):
    8 bits, zero margin, zero truncation, exact vote adder."""
    g = np.zeros(3 * n_comparators + 1, dtype=np.float32)
    g[0:-1:3] = 0.999  # precision -> 8 bits
    g[1:-1:3] = 0.5    # margin -> 0
    # trunc genes (2::3) and the vote gene (last) stay 0.0 -> exact cells
    return g
