"""CART Decision-Tree training (gini, expand-until-pure), pure numpy.

The paper trains with scikit-learn, nodes "expanded until all leaves are pure"
(max number of leaves). We reimplement CART with histogram-based splitting on
the 8-bit master grid: inputs are normalized to [0,1] and the bespoke hardware
evaluates 8-bit (or lower) comparators anyway, so candidate thresholds live on
the 2^8 grid by construction. Within that grid the search is exact.

Thresholds are stored as floats T = (t8 + 0.5) / 256 so that the master 8-bit
integer code is recovered exactly by floor(T * 256) = t8 (see core.quant).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.datasets.synthetic import quantize_u8

MASTER_BITS = 8
GRID = 1 << MASTER_BITS


@dataclasses.dataclass
class TreeArrays:
    """Flattened decision tree.

    Internal node semantics: go RIGHT iff x_int(feature) > threshold_int,
    i.e. x > threshold in the reals. Node 0 is the root.
    """

    feature: np.ndarray      # int32[n_nodes], -1 for leaves
    threshold: np.ndarray    # float32[n_nodes], 0 for leaves; in (0,1)
    left: np.ndarray         # int32[n_nodes], -1 for leaves
    right: np.ndarray        # int32[n_nodes], -1 for leaves
    leaf_class: np.ndarray   # int32[n_nodes], -1 for internal
    n_classes: int

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])

    @property
    def is_leaf(self) -> np.ndarray:
        return self.feature < 0

    @property
    def n_comparators(self) -> int:
        return int((self.feature >= 0).sum())

    @property
    def n_leaves(self) -> int:
        return int((self.feature < 0).sum())

    @property
    def depth(self) -> int:
        depth = np.zeros(self.n_nodes, dtype=np.int32)
        order = range(self.n_nodes)
        for i in order:  # children always appear after parents
            if self.feature[i] >= 0:
                depth[self.left[i]] = depth[i] + 1
                depth[self.right[i]] = depth[i] + 1
        return int(depth.max()) if self.n_nodes else 0


def _gini_split_scores(hist: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """hist: (F, B, C) class counts per (feature, bin).

    Returns (best_score[F], best_bin[F]) where score is the weighted gini of
    children for the split ``x8 <= t`` / ``x8 > t`` at each bin t, minimized.
    Invalid splits (empty side) score +inf.
    """
    cum = hist.cumsum(axis=1).astype(np.float64)            # (F, B, C) left counts
    total = cum[:, -1:, :]                                   # (F, 1, C)
    n_left = cum.sum(axis=2)                                 # (F, B)
    n_total = total.sum(axis=2)                              # (F, 1)
    n_right = n_total - n_left
    right = total - cum
    nl = np.maximum(n_left, 1e-12)
    nr = np.maximum(n_right, 1e-12)
    gini_l = 1.0 - np.square(cum / nl[..., None]).sum(axis=2)
    gini_r = 1.0 - np.square(right / nr[..., None]).sum(axis=2)
    score = n_left * gini_l + n_right * gini_r               # (F, B)
    score = np.where((n_left == 0) | (n_right == 0), np.inf, score)
    best_bin = score.argmin(axis=1)
    best_score = score[np.arange(score.shape[0]), best_bin]
    return best_score, best_bin


def _node_histogram(x8: np.ndarray, y: np.ndarray, n_classes: int) -> np.ndarray:
    """Class-count histogram, shape (F, GRID, C), via one flat bincount."""
    n, f = x8.shape
    base = (np.arange(f, dtype=np.int64) * GRID)[None, :]     # (1, F)
    flat = (base + x8.astype(np.int64)) * n_classes + y[:, None].astype(np.int64)
    counts = np.bincount(flat.ravel(), minlength=f * GRID * n_classes)
    return counts.reshape(f, GRID, n_classes)


def train_tree(
    x: np.ndarray,
    y: np.ndarray,
    n_classes: int,
    max_depth: int = 64,
    min_samples_leaf: int = 1,
) -> TreeArrays:
    """Grow a CART tree until leaves are pure (or unsplittable on the grid)."""
    x8 = quantize_u8(x, MASTER_BITS).astype(np.int16)
    n = x.shape[0]

    feature, threshold, left, right, leaf_cls = [], [], [], [], []

    def new_node() -> int:
        feature.append(-1)
        threshold.append(0.0)
        left.append(-1)
        right.append(-1)
        leaf_cls.append(-1)
        return len(feature) - 1

    # stack of (node_id, sample_indices, depth); children get ids > parent
    root = new_node()
    stack = [(root, np.arange(n), 0)]
    while stack:
        node, idx, depth = stack.pop()
        ys = y[idx]
        counts = np.bincount(ys, minlength=n_classes)
        majority = int(counts.argmax())
        pure = counts.max() == idx.size
        if pure or depth >= max_depth or idx.size < 2 * min_samples_leaf:
            leaf_cls[node] = majority
            continue
        hist = _node_histogram(x8[idx], ys, n_classes)
        best_score, best_bin = _gini_split_scores(hist)
        f = int(best_score.argmin())
        if not np.isfinite(best_score[f]):
            leaf_cls[node] = majority           # all features constant on grid
            continue
        t8 = int(best_bin[f])
        go_right = x8[idx, f] > t8
        idx_l, idx_r = idx[~go_right], idx[go_right]
        if idx_l.size < min_samples_leaf or idx_r.size < min_samples_leaf:
            leaf_cls[node] = majority
            continue
        # parent gini must strictly improve, else stop (ties on the grid)
        parent_gini = (1.0 - np.square(counts / idx.size).sum()) * idx.size
        if best_score[f] >= parent_gini - 1e-12:
            leaf_cls[node] = majority
            continue
        feature[node] = f
        threshold[node] = (t8 + 0.5) / GRID
        l_id, r_id = new_node(), new_node()
        left[node], right[node] = l_id, r_id
        stack.append((l_id, idx_l, depth + 1))
        stack.append((r_id, idx_r, depth + 1))

    return TreeArrays(
        feature=np.asarray(feature, dtype=np.int32),
        threshold=np.asarray(threshold, dtype=np.float32),
        left=np.asarray(left, dtype=np.int32),
        right=np.asarray(right, dtype=np.int32),
        leaf_class=np.asarray(leaf_cls, dtype=np.int32),
        n_classes=n_classes,
    )


def predict_numpy(tree: TreeArrays, x: np.ndarray) -> np.ndarray:
    """Reference traversal prediction (float thresholds), vectorized descent."""
    node = np.zeros(x.shape[0], dtype=np.int64)
    for _ in range(tree.n_nodes):  # upper bound on depth
        f = tree.feature[node]
        done = f < 0
        if done.all():
            break
        fx = x[np.arange(x.shape[0]), np.maximum(f, 0)]
        go_right = fx > tree.threshold[node]
        nxt = np.where(go_right, tree.right[node], tree.left[node])
        node = np.where(done, node, nxt)
    return tree.leaf_class[node].astype(np.int32)
