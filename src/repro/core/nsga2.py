"""Vectorized NSGA-II (Deb et al. 2002), the paper's design-space explorer.

Faithful to the paper's configuration: elitist (mu+lambda), binary tournament
selection on (rank, crowding), simulated binary crossover, polynomial
mutation, fast non-dominated sort, crowding-distance truncation.

Everything is fixed-shape jnp so a whole generation is ONE compiled program —
and `make_chunk` scans that program over a generation chunk so a whole
checkpoint interval is one dispatch (DESIGN.md §9). Fitness is a vmapped
batch; the domination matrix is a dense (P, P) block, auto-routed to the
Pallas kernel in repro.kernels.domination above DOMINATION_KERNEL_MIN_POP;
fronts are peeled with a while_loop; crowding uses masked sorts. Population
parallelism maps onto the mesh in repro.core.dist.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

INF = jnp.inf
_BIG = 1e9

# Number of *rows* of the domination relation at which `non_dominated_sort`
# routes through the blocked Pallas kernel (repro.kernels.domination) instead
# of the pure-jnp broadcast. The row count is the LOCAL population slab: the
# monolithic sort hands the full pool (rows == columns == pool P, and inside
# the GA step the pool is the combined parent+offspring set 2P, so the kernel
# engages from pop_size >= DOMINATION_KERNEL_MIN_POP / 2); the mesh-sharded
# hierarchical sort hands each shard's (P_local, P_global) row block, so a
# population sharded 8 ways routes on P/8 — small shards skip the Pallas
# launch overhead even when the global pool is huge (DESIGN.md §13). The jnp
# path stays the bit-exact oracle (the matrix is boolean, so "bit-exact" is
# plain equality) — see DESIGN.md §9.
DOMINATION_KERNEL_MIN_POP = 512


def domination_matrix(objs: jnp.ndarray,
                      against: jnp.ndarray | None = None) -> jnp.ndarray:
    """objs (Pi, M), minimized. out[i, j] = True iff objs[i] dominates
    against[j] (default ``against = objs`` — the square pool-vs-pool case)."""
    a = objs[:, None, :]  # i
    b = (objs if against is None else against)[None, :, :]  # j
    return jnp.all(a <= b, axis=-1) & jnp.any(a < b, axis=-1)


def _kernel_domination_available() -> bool:
    """Auto-routing engages only on a real TPU. Off-TPU the kernel runs in
    the Pallas interpreter — a bit-exact correctness fallback for explicit
    use (cfg.domination_fn), never a win to route to automatically."""
    return jax.default_backend() == "tpu"


def _dispatch_domination(objs: jnp.ndarray,
                         against: jnp.ndarray | None = None) -> jnp.ndarray:
    """Pure-jnp domination below DOMINATION_KERNEL_MIN_POP rows, Pallas above.

    Routing is on ``objs.shape[0]`` — the local (post-shard) row count, not
    the global pool size — so a small per-shard slab of a large sharded pool
    never pays the kernel's launch overhead. Shapes are static under jit, so
    the routing resolves at trace time — no runtime branching inside the
    compiled program."""
    if (objs.shape[0] >= DOMINATION_KERNEL_MIN_POP
            and _kernel_domination_available()):
        try:
            from repro.kernels import ops as _kops
        except ImportError:  # kernels package unavailable: oracle path
            return domination_matrix(objs, against)
        if against is None:
            return _kops.domination_matrix_bool(objs)
        return _kops.domination_block_bool(objs, against)
    return domination_matrix(objs, against)


def _peel_fronts(n_dominators: jnp.ndarray, dec_fn) -> jnp.ndarray:
    """Front-peeling while_loop shared by the monolithic and sharded sorts.

    ``n_dominators`` (P,) int32 — how many pool members dominate each j;
    ``dec_fn(current)`` — given the (P,) bool mask of the front being peeled,
    return the (P,) int32 count of dominators each j loses. The monolithic
    sort reduces its full (P, P) matrix; the sharded sort reduces its local
    (P_local, P) row block and merges with a psum — integer sums partition
    exactly over shards, so both produce identical ranks (DESIGN.md §13).
    """
    p = n_dominators.shape[0]

    def body(state):
        rank, counts, r = state
        current = (counts == 0) & (rank < 0)
        rank = jnp.where(current, r, rank)
        # removing `current` decrements the dominator count of their dominatees
        counts = jnp.where(rank < 0, counts - dec_fn(current), -1)
        return rank, counts, r + 1

    def cond(state):
        rank, _, _ = state
        return jnp.any(rank < 0)

    rank0 = jnp.full((p,), -1, dtype=jnp.int32)
    counts0 = jnp.where(rank0 < 0, n_dominators, -1)
    rank, _, _ = jax.lax.while_loop(cond, body, (rank0, counts0, jnp.int32(0)))
    return rank


def non_dominated_sort(objs: jnp.ndarray, dom: jnp.ndarray | None = None) -> jnp.ndarray:
    """Returns integer rank per individual (0 = first/pareto front)."""
    if dom is None:
        dom = _dispatch_domination(objs)
    n_dominators = dom.sum(axis=0).astype(jnp.int32)  # how many dominate j

    def dec(current):
        return (dom & current[:, None]).sum(axis=0).astype(jnp.int32)

    return _peel_fronts(n_dominators, dec)


def crowding_distance(objs: jnp.ndarray, rank: jnp.ndarray) -> jnp.ndarray:
    """Crowding distance computed per-front with masked sorts (fixed shape).

    The per-objective pass is vmapped over the objective axis instead of a
    Python loop of M sequential sort programs, so all objectives sort at
    once. Bit-identical to the historical loop (tests pin it against an
    independent loop oracle): per-axis contributions are non-negative, the
    scatter indices are a permutation, and the contributions are added
    sequentially in axis order — a tree-shaped `sum` would reassociate the
    f32 adds and drift by an ulp from generation to generation.
    """
    p, m = objs.shape

    def one_axis(v):
        # sort within fronts: composite key pushes other fronts far away
        key = rank.astype(jnp.float32) * _BIG + v
        order = jnp.argsort(key)
        v_s = v[order]
        r_s = rank[order]
        # neighbours within the same front
        prev_ok = jnp.concatenate([jnp.array([False]), r_s[1:] == r_s[:-1]])
        next_ok = jnp.concatenate([r_s[:-1] == r_s[1:], jnp.array([False])])
        v_prev = jnp.concatenate([v_s[:1], v_s[:-1]])
        v_next = jnp.concatenate([v_s[1:], v_s[-1:]])
        # per-front objective range for normalization
        fmin = jnp.full((p,), jnp.inf).at[r_s].min(v_s)
        fmax = jnp.full((p,), -jnp.inf).at[r_s].max(v_s)
        span = jnp.maximum((fmax - fmin)[r_s], 1e-12)
        d = jnp.where(prev_ok & next_ok, (v_next - v_prev) / span, jnp.inf)
        return jnp.zeros((p,), jnp.float32).at[order].add(
            jnp.where(jnp.isinf(d), _BIG, d))

    contribs = jax.vmap(one_axis, in_axes=1)(objs)  # (M, P)
    dist = contribs[0]
    for k in range(1, m):
        dist = dist + contribs[k]
    return dist


def _tournament(key, rank, crowd, n_out):
    p = rank.shape[0]
    k1, k2 = jax.random.split(key)
    a = jax.random.randint(k1, (n_out,), 0, p)
    b = jax.random.randint(k2, (n_out,), 0, p)
    # lower rank wins; tie -> higher crowding wins; tie -> a
    a_wins = (rank[a] < rank[b]) | ((rank[a] == rank[b]) & (crowd[a] >= crowd[b]))
    return jnp.where(a_wins, a, b)


def _sbx(key, parents_a, parents_b, eta_c, p_cross):
    """Simulated binary crossover on [0,1] genes."""
    ku, kc, kv = jax.random.split(key, 3)
    u = jax.random.uniform(ku, parents_a.shape)
    beta = jnp.where(
        u <= 0.5,
        (2.0 * u) ** (1.0 / (eta_c + 1.0)),
        (1.0 / (2.0 * (1.0 - u))) ** (1.0 / (eta_c + 1.0)),
    )
    c1 = 0.5 * ((1 + beta) * parents_a + (1 - beta) * parents_b)
    c2 = 0.5 * ((1 - beta) * parents_a + (1 + beta) * parents_b)
    do = jax.random.uniform(kc, parents_a.shape[:1]) < p_cross
    c1 = jnp.where(do[:, None], c1, parents_a)
    c2 = jnp.where(do[:, None], c2, parents_b)
    swap = jax.random.uniform(kv, parents_a.shape) < 0.5
    o1 = jnp.where(swap, c1, c2)
    o2 = jnp.where(swap, c2, c1)
    return jnp.clip(o1, 0.0, 1.0), jnp.clip(o2, 0.0, 1.0)


def _poly_mutation(key, genes, eta_m, p_mut):
    km, ku = jax.random.split(key)
    u = jax.random.uniform(ku, genes.shape)
    delta = jnp.where(
        u < 0.5,
        (2.0 * u) ** (1.0 / (eta_m + 1.0)) - 1.0,
        1.0 - (2.0 * (1.0 - u)) ** (1.0 / (eta_m + 1.0)),
    )
    mask = jax.random.uniform(km, genes.shape) < p_mut
    return jnp.clip(genes + jnp.where(mask, delta, 0.0), 0.0, 1.0)


@dataclasses.dataclass
class NSGA2Config:
    pop_size: int = 64
    n_generations: int = 40
    eta_crossover: float = 20.0
    eta_mutation: float = 20.0
    p_crossover: float = 0.9
    p_mutation: float | None = None  # default 1/n_genes
    domination_fn: Callable | None = None  # e.g. Pallas kernel; default jnp


@dataclasses.dataclass
class NSGA2State:
    genes: jnp.ndarray   # (P, G)
    objs: jnp.ndarray    # (P, M)
    rank: jnp.ndarray    # (P,)
    crowd: jnp.ndarray   # (P,)
    key: jnp.ndarray
    generation: jnp.ndarray


jax.tree_util.register_pytree_node(
    NSGA2State,
    lambda s: ((s.genes, s.objs, s.rank, s.crowd, s.key, s.generation), None),
    lambda _, c: NSGA2State(*c),
)


def init_state(key, fitness_fn, n_genes: int, cfg: NSGA2Config,
               seed_genes=None) -> NSGA2State:
    """seed_genes (K, n_genes): known-good designs injected into the initial
    population (e.g. the exact bespoke design + jittered copies). Beyond-paper
    improvement: for high-gene-count trees (HAR: 1000+ genes) random init
    never recovers the near-exact region within realistic budgets."""
    kinit, kloop, kjit = jax.random.split(key, 3)
    genes = jax.random.uniform(kinit, (cfg.pop_size, n_genes))
    if seed_genes is not None:
        seed_genes = jnp.atleast_2d(jnp.asarray(seed_genes))
        k = seed_genes.shape[0]
        n_seed = min(cfg.pop_size // 2, max(k, cfg.pop_size // 8))
        reps = jnp.tile(seed_genes, ((n_seed + k - 1) // k, 1))[:n_seed]
        jitter = jax.random.normal(kjit, reps.shape) * 0.03
        jitter = jitter.at[:k].set(0.0)  # keep pristine seeds
        genes = genes.at[:n_seed].set(jnp.clip(reps + jitter, 0.0, 1.0))
    objs = fitness_fn(genes)
    dom_fn = cfg.domination_fn or _dispatch_domination
    rank = non_dominated_sort(objs, dom_fn(objs))
    crowd = crowding_distance(objs, rank)
    return NSGA2State(genes, objs, rank, crowd, kloop, jnp.int32(0))


def make_step(fitness_fn, cfg: NSGA2Config):
    """One (mu+lambda) generation, jittable."""
    dom_fn = cfg.domination_fn or _dispatch_domination

    def step(state: NSGA2State) -> NSGA2State:
        p, g = state.genes.shape
        p_mut = cfg.p_mutation if cfg.p_mutation is not None else 1.0 / g
        key, ksel, kx, km = jax.random.split(state.key, 4)

        idx = _tournament(ksel, state.rank, state.crowd, p)
        pa, pb = state.genes[idx[0::2]], state.genes[idx[1::2]]
        o1, o2 = _sbx(kx, pa, pb, cfg.eta_crossover, cfg.p_crossover)
        children = jnp.concatenate([o1, o2], axis=0)[:p]
        children = _poly_mutation(km, children, cfg.eta_mutation, p_mut)
        c_objs = fitness_fn(children)

        pool_genes = jnp.concatenate([state.genes, children], axis=0)
        pool_objs = jnp.concatenate([state.objs, c_objs], axis=0)
        rank = non_dominated_sort(pool_objs, dom_fn(pool_objs))
        crowd = crowding_distance(pool_objs, rank)
        # elitist truncation: (rank asc, crowding desc)
        order = jnp.argsort(rank.astype(jnp.float32) * _BIG - jnp.minimum(crowd, _BIG / 2))
        keep = order[:p]
        return NSGA2State(
            pool_genes[keep], pool_objs[keep], rank[keep], crowd[keep],
            key, state.generation + 1,
        )

    return step


def make_chunk(fitness_fn, cfg: NSGA2Config, chunk_len: int):
    """`chunk_len` generations as ONE device program: lax.scan over make_step.

    The device-resident generation loop (DESIGN.md §9): instead of the host
    dispatching one jitted step per generation (a host round-trip each), a
    whole chunk — typically one checkpoint interval — is a single dispatch
    and a single device->host transfer. The scan body is exactly `make_step`,
    so a chunked run is bit-identical to the per-generation loop (tests
    enforce this)."""
    if chunk_len < 1:
        raise ValueError(f"chunk_len must be >= 1, got {chunk_len}")
    step = make_step(fitness_fn, cfg)

    def chunk(state: NSGA2State) -> NSGA2State:
        return jax.lax.scan(lambda s, _: (step(s), None), state, None,
                            length=chunk_len)[0]

    return chunk


def make_batched_init(fitness_from_ctx, n_genes: int, cfg: NSGA2Config,
                      seed_genes=None):
    """`init_state` vmapped over a leading problem axis (DESIGN.md §11).

    `fitness_from_ctx(ctx, pop)` evaluates one problem's population given its
    per-problem context pytree (e.g. a padded `sweep.PaddedProblem`); the
    returned function maps stacked `(keys, ctxs)` — both with a leading
    problem axis — to a stacked `NSGA2State`, initializing every problem in
    ONE dispatch (jit the result). `seed_genes` is shared across problems
    (the sweep pads every bucket member to the same chromosome length, and
    the exact design is the same inert-padded encoding for all)."""

    def init_one(key, ctx):
        return init_state(key, lambda pop: fitness_from_ctx(ctx, pop),
                          n_genes, cfg, seed_genes=seed_genes)

    return jax.vmap(init_one)


def make_batched_chunk(fitness_from_ctx, cfg: NSGA2Config, chunk_len: int):
    """`make_chunk` vmapped over a leading problem axis (DESIGN.md §11).

    One dispatch of the returned function advances EVERY problem in the
    batch by `chunk_len` generations: the scanned generation program (§9) is
    vmapped over stacked per-problem contexts, so the whole bucket of
    campaigns costs one host round-trip. Per-problem arithmetic is
    bit-identical to running `make_chunk` problem-by-problem (the sweep's
    serial oracle; tests pin it) — every cross-lane reduction the GA step
    performs is either integer-valued in f32 or elementwise."""

    def chunk_one(state, ctx):
        return make_chunk(lambda pop: fitness_from_ctx(ctx, pop),
                          cfg, chunk_len)(state)

    return jax.vmap(chunk_one)


def run(key, fitness_fn, n_genes: int, cfg: NSGA2Config,
        state: NSGA2State | None = None, jit: bool = True,
        seed_genes=None) -> NSGA2State:
    """Run the GA; `state` allows checkpoint/restart continuation.

    jit=False runs the generation eagerly so `fitness_fn` may be a host
    (numpy) function — used by the LM mixed-precision search where fitness
    re-quantizes weight tensors on the host."""
    if state is None:
        state = init_state(key, fitness_fn, n_genes, cfg, seed_genes)
    step = make_step(fitness_fn, cfg)
    if jit:
        step = jax.jit(step)
    for _ in range(cfg.n_generations):
        state = step(state)
    return state


def pareto_front(objs: jnp.ndarray, genes: jnp.ndarray):
    """Extract the non-dominated set, sorted by the first objective."""
    rank = non_dominated_sort(objs)
    mask = rank == 0
    import numpy as np
    objs_np = np.asarray(objs)[np.asarray(mask)]
    genes_np = np.asarray(genes)[np.asarray(mask)]
    order = np.argsort(objs_np[:, 0])
    return objs_np[order], genes_np[order]
