"""`pareto.json` schema: one validated contract for writer and loader.

`engine.write_pareto_artifact` (the writer) and `load_pareto_artifact`
(the serving loader, DESIGN.md §14) share the key sets below, so the two
sides cannot drift apart silently: the writer validates its payload through
`validate_payload` before dumping, and the loader validates on the way in —
a missing or unknown key raises a `ValueError` naming the offending keys
instead of surfacing as a `KeyError` deep inside the serving runtime.

The artifact is fully self-contained: besides the trained float `threshold`
and comparator `feature` map it records the block-diagonal super-tree
layout (`path`, `path_len`, `n_neg`, `leaf_class`, per-tree
`tree_comparators`/`tree_leaves`), so `ParetoArtifact.ptrees()` rebuilds
the per-tree `ParallelTree`s — and from there the gate-level netlist, RTL,
or a `ClassifyServer` — from the JSON alone, no dataset or training run
required. Each pareto point stores the *decoded* design — pre-truncation
`bits` + substituted integer thresholds `t_int`, plus the cross-layer
approximation config of DESIGN.md §16: per-comparator `trunc` LSB-drop
counts and the forest-level `vote_adder` mode — sidestepping the rounded
`genes` entirely: re-serving a point reproduces its recorded accuracy
bit-exactly. `trunc`/`vote_adder` values are validated on write AND load
(range [0, MAX_TRUNC], mode in VOTE_ADDER_MODES) with named `ValueError`s,
same as the key sets.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core.tree import ParallelTree

# The writer/loader contract. OPTIONAL keys may be absent; anything outside
# REQUIRED | OPTIONAL is an error in both directions (the artifact may only
# grow by extending these sets, keeping old loaders loud about new files and
# new loaders loud about hand-mangled ones).
REQUIRED_TOP_KEYS = frozenset({
    "backend", "wall_s", "n_evaluations", "n_dispatches",
    "n_trees", "n_comparators", "n_classes",
    "tree_comparators", "tree_leaves",
    "feature", "threshold", "path", "path_len", "n_neg", "leaf_class",
    "exact_accuracy", "exact_area_mm2", "rtl_verified", "pareto",
})
OPTIONAL_TOP_KEYS = frozenset({"dataset", "family"})
REQUIRED_POINT_KEYS = frozenset({
    "acc_loss", "norm_area", "area_mm2", "area_netlist_mm2",
    "netlist_gates", "bits", "margin", "t_int", "trunc", "vote_adder",
    "genes",
})
OPTIONAL_POINT_KEYS = frozenset({"rtl", "verified"})


def _check_keys(have, required, optional, where: str) -> None:
    have = set(have)
    missing = sorted(required - have)
    unknown = sorted(have - required - optional)
    problems = []
    if missing:
        problems.append(f"missing keys {missing}")
    if unknown:
        problems.append(f"unknown keys {unknown}")
    if problems:
        raise ValueError(
            f"pareto artifact {where}: {'; '.join(problems)} "
            f"(expected {sorted(required)} + optional {sorted(optional)})")


def validate_payload(payload: dict, where: str = "payload") -> dict:
    """Validate a pareto.json payload against the shared schema.

    Checks the top-level and per-point key sets both ways (missing AND
    unknown keys raise `ValueError`), plus the cross-field layout
    invariants the loader's array reconstruction depends on. Returns the
    payload unchanged so callers can chain it.
    """
    if not isinstance(payload, dict):
        raise ValueError(f"pareto artifact {where}: expected a JSON object, "
                         f"got {type(payload).__name__}")
    _check_keys(payload, REQUIRED_TOP_KEYS, OPTIONAL_TOP_KEYS, where)
    family = payload.get("family", "tree")
    if family != "tree":
        raise ValueError(
            f"pareto artifact {where}: family {family!r} does not match the "
            f"tree schema (load through repro.families.family_of_payload)")
    points = payload["pareto"]
    if not isinstance(points, list):
        raise ValueError(f"pareto artifact {where}: 'pareto' must be a list")
    for i, point in enumerate(points):
        if not isinstance(point, dict):
            raise ValueError(
                f"pareto artifact {where}: pareto[{i}] must be an object")
        _check_keys(point, REQUIRED_POINT_KEYS, OPTIONAL_POINT_KEYS,
                    f"{where}.pareto[{i}]")

    n = payload["n_comparators"]
    l = len(payload["path_len"])
    if sum(payload["tree_comparators"]) != n:
        raise ValueError(
            f"pareto artifact {where}: tree_comparators "
            f"{payload['tree_comparators']} do not sum to n_comparators={n}")
    if sum(payload["tree_leaves"]) != l:
        raise ValueError(
            f"pareto artifact {where}: tree_leaves {payload['tree_leaves']} "
            f"do not sum to the {l} leaves of path_len")
    if len(payload["tree_comparators"]) != payload["n_trees"]:
        raise ValueError(
            f"pareto artifact {where}: {len(payload['tree_comparators'])} "
            f"tree_comparators entries for n_trees={payload['n_trees']}")
    for key in ("feature", "threshold"):
        if len(payload[key]) != n:
            raise ValueError(
                f"pareto artifact {where}: {key!r} has {len(payload[key])} "
                f"entries, expected n_comparators={n}")
    if len(payload["path"]) != l or any(len(r) != n for r in payload["path"]):
        raise ValueError(
            f"pareto artifact {where}: 'path' must be {l} rows x {n} "
            f"columns (leaves x comparators)")
    for key in ("n_neg", "leaf_class"):
        if len(payload[key]) != l:
            raise ValueError(
                f"pareto artifact {where}: {key!r} has {len(payload[key])} "
                f"entries, expected {l} leaves")
    from repro.core import quant

    for i, point in enumerate(points):
        for key in ("bits", "margin", "t_int", "trunc"):
            if len(point[key]) != n:
                raise ValueError(
                    f"pareto artifact {where}: pareto[{i}].{key} has "
                    f"{len(point[key])} entries, expected n_comparators={n}")
        bad_trunc = [t for t in point["trunc"]
                     if not (isinstance(t, int)
                             and 0 <= t <= quant.MAX_TRUNC)]
        if bad_trunc:
            raise ValueError(
                f"pareto artifact {where}: pareto[{i}].trunc entries "
                f"{bad_trunc} out of range [0, {quant.MAX_TRUNC}]")
        if point["vote_adder"] not in quant.VOTE_ADDER_MODES:
            raise ValueError(
                f"pareto artifact {where}: pareto[{i}].vote_adder "
                f"{point['vote_adder']!r} not in {quant.VOTE_ADDER_MODES}")
    return payload


@dataclasses.dataclass
class ParetoArtifact:
    """A loaded, validated `pareto.json`: design layout + pareto points.

    Arrays are reconstructed as numpy with the `SearchProblem` dtypes, so
    the artifact plugs straight into `kernels.ops.prepare_operands`,
    `core.netlist.build_circuit` (via `ptrees()`) and
    `runtime.classify.ClassifyServer`.
    """

    payload: dict
    feature: np.ndarray      # (N,) int32
    threshold: np.ndarray    # (N,) float32
    path: np.ndarray         # (L, N) int8 block-diagonal super-tree
    path_len: np.ndarray     # (L,) int32
    n_neg: np.ndarray        # (L,) int32
    leaf_class: np.ndarray   # (L,) int32
    n_trees: int
    n_classes: int
    tree_comparators: tuple
    tree_leaves: tuple
    exact_accuracy: float
    exact_area_mm2: float
    dataset: str | None
    points: list
    family: str = "tree"

    @property
    def n_comparators(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_leaves(self) -> int:
        return int(self.leaf_class.shape[0])

    def point_design(self, i: int):
        """Pareto point `i`'s decoded design (DESIGN.md §14, §16):
        (bits, t_int, trunc, vote_adder) — `bits`/`t_int`/`trunc` are (N,)
        int arrays with pre-truncation precision/thresholds, `vote_adder`
        is "exact" or "approx". Consumers fold `trunc` into effective
        operands (`kernels.ops.prepare_design`, `netlist.build_circuit`)."""
        point = self.points[i]
        return (np.asarray(point["bits"], np.int32),
                np.asarray(point["t_int"], np.int32),
                np.asarray(point["trunc"], np.int32),
                str(point["vote_adder"]))

    def point_accuracy(self, i: int) -> float:
        """The accuracy this point scored on the search's test split."""
        return self.exact_accuracy - float(self.points[i]["acc_loss"])

    def best_under_loss(self, max_loss: float = 0.01) -> int | None:
        """Index of the smallest-area point within the loss budget."""
        ok = [i for i, p in enumerate(self.points)
              if p["acc_loss"] <= max_loss + 1e-9]
        if not ok:
            return None
        return min(ok, key=lambda i: self.points[i]["norm_area"])

    def ptrees(self) -> list:
        """Rebuild the per-tree `ParallelTree`s from the stored layout.

        The same block-diagonal slicing as `search.problem_ptrees`, driven
        from the artifact's arrays instead of a `SearchProblem` — the
        hardware pipeline (netlist build, RTL emission) and the serving
        runtime re-materialize a design from the JSON alone.
        """
        ptrees, n_off, l_off = [], 0, 0
        for n_k, l_k in zip(self.tree_comparators, self.tree_leaves):
            block = self.path[l_off:l_off + l_k, n_off:n_off + n_k]
            if n_k == 0:  # single-leaf tree: ParallelTree keeps a dummy col
                block = np.zeros((l_k, 1), np.int8)
            ptrees.append(ParallelTree(
                feature=self.feature[n_off:n_off + n_k],
                threshold=self.threshold[n_off:n_off + n_k],
                path=np.ascontiguousarray(block),
                path_len=self.path_len[l_off:l_off + l_k],
                n_neg=self.n_neg[l_off:l_off + l_k],
                leaf_class=self.leaf_class[l_off:l_off + l_k],
                n_classes=self.n_classes,
            ))
            n_off += n_k
            l_off += l_k
        return ptrees


def from_payload(payload: dict, where: str = "payload"):
    """Validate a payload dict and materialize the family's artifact.

    Legacy payloads (no `family` key) and `family: "tree"` ones validate
    against the tree schema here; any other family tag dispatches to that
    family's own loader (`repro.families`), so every consumer of
    `load_pareto_artifact` transparently handles MLP artifacts too.
    """
    if isinstance(payload, dict) and payload.get("family", "tree") != "tree":
        from repro.families import family_of_payload
        return family_of_payload(payload).load_artifact(payload)
    validate_payload(payload, where)
    return ParetoArtifact(
        payload=payload,
        feature=np.asarray(payload["feature"], np.int32),
        threshold=np.asarray(payload["threshold"], np.float32),
        path=np.asarray(payload["path"], np.int8),
        path_len=np.asarray(payload["path_len"], np.int32),
        n_neg=np.asarray(payload["n_neg"], np.int32),
        leaf_class=np.asarray(payload["leaf_class"], np.int32),
        n_trees=int(payload["n_trees"]),
        n_classes=int(payload["n_classes"]),
        tree_comparators=tuple(payload["tree_comparators"]),
        tree_leaves=tuple(payload["tree_leaves"]),
        exact_accuracy=float(payload["exact_accuracy"]),
        exact_area_mm2=float(payload["exact_area_mm2"]),
        dataset=payload.get("dataset"),
        points=list(payload["pareto"]),
    )


def load_pareto_artifact(path: str):
    """Load + validate a `pareto.json` (any family, dispatched by tag)."""
    with open(path) as f:
        payload = json.load(f)
    return from_payload(payload, where=path)
