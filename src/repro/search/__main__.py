"""CLI for the unified search engine.

    PYTHONPATH=src python -m repro.search --dataset seeds
    PYTHONPATH=src python -m repro.search --dataset seeds --trees 4 \
        --backend kernel --pop 64 --gens 40 --out runs/seeds_forest
    PYTHONPATH=src python -m repro.search sweep --datasets all --report
    PYTHONPATH=src python -m repro.search serve --pareto OUT/pareto.json
    PYTHONPATH=src python -m repro.search faults --pareto OUT/pareto.json

The `serve` subcommand loads a searched design back out of `pareto.json`
and serves feature-vector queries through `runtime.classify.ClassifyServer`
(power-of-two batch buckets + donated ping-pong buffers, DESIGN.md §14),
asserting the served accuracy reproduces the artifact's recorded point and
— with `--verify-netlist` — that every prediction is bit-exact against the
gate-level netlist simulator.

The `sweep` subcommand runs the paper's whole multi-dataset campaign as a
handful of vmapped programs (DESIGN.md §11): problems are padded to bucket
boundaries, stacked, and advanced with one device dispatch per bucket per
stage; per-dataset `pareto.json` artifacts land under `OUT/<dataset>/` and
`--report` scores every dataset against the paper's Tables I/II
(`OUT/sweep_report.json` + `OUT/REPORT.md`).

Trains the exact bespoke tree (or a bootstrap forest with --trees K), runs
the NSGA-II dual-approximation search on the selected backend, prints the
pareto front and the best design under the 1% accuracy-loss budget, and —
with --out — writes pareto.json plus the bespoke Verilog of the selected
design (trees AND forests: per-tree modules + the majority-vote adder tree,
DESIGN.md §10). `--emit-rtl` additionally writes every pareto point's
Verilog under OUT/rtl/; `--verify-rtl` simulates each point's gate-level
netlist over the full test set and asserts bit-exactness against the tensor
program and the kernel backend. `--checkpoint-every N --resume` gives
kill-safe long runs on every backend (islands included); see the README's
CLI reference for the flag-by-flag walkthrough.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import area
from repro.datasets import DATASET_SPECS, load_dataset
from repro import search


def _load_artifact_or_exit(path: str):
    """Load a pareto.json for a CLI, or exit(2) with a one-line error.

    A missing, truncated, or schema-violating artifact is an operator
    mistake, not a bug — so the CLIs report the named error on stderr and
    exit non-zero instead of dumping a traceback.
    """
    import sys

    try:
        return search.load_pareto_artifact(path)
    except (OSError, ValueError) as e:
        msg = str(e).strip() or type(e).__name__
        print(f"error: pareto artifact {path}: {type(e).__name__}: {msg}",
              file=sys.stderr)
        raise SystemExit(2)


def sweep_main(argv=None) -> None:
    """`python -m repro.search sweep`: the batched full-suite campaign."""
    from repro.search import sweep as sweep_mod

    ap = argparse.ArgumentParser(prog="python -m repro.search sweep")
    ap.add_argument("--datasets", default="all",
                    help="comma-separated dataset names, or 'all' for the "
                         "paper's full 10-dataset suite")
    ap.add_argument("--trees", type=int, default=1,
                    help="1 = single bespoke DT per dataset; K>1 = bootstrap "
                         "forest per dataset (joint chromosome)")
    ap.add_argument("--mlp-datasets", default="",
                    help="comma-separated datasets to ALSO search as printed "
                         "MLPs (campaign keys suffixed _mlp); the bucket "
                         "planner keeps families in separate buckets")
    ap.add_argument("--hidden", type=int, default=16,
                    help="printed-MLP hidden-layer width for --mlp-datasets")
    ap.add_argument("--pop", type=int, default=64)
    ap.add_argument("--gens", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/sweep",
                    help="artifact root: per-dataset pareto.json under "
                         "OUT/<dataset>/, report at OUT/sweep_report.json")
    ap.add_argument("--max-buckets", type=int,
                    default=sweep_mod.DEFAULT_MAX_BUCKETS,
                    help="merge shape buckets down to at most this many "
                         "vmapped programs")
    ap.add_argument("--serial", action="store_true",
                    help="run the per-problem serial loop (the bit-exact "
                         "oracle the vmapped path is tested against)")
    ap.add_argument("--mesh", default=None,
                    help="device mesh spec (DESIGN.md §13): 'KxN' = K-way "
                         "bucket axis x N-way population axis, 'N'/'auto' = "
                         "population axis only; default: single device")
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory: "
                         "re-runs skip recompiling every bucket shape")
    ap.add_argument("--emit-rtl", action="store_true",
                    help="write every pareto point's Verilog under "
                         "OUT/<dataset>/rtl/")
    ap.add_argument("--verify-rtl", action="store_true",
                    help="netlist-simulate every pareto point of every "
                         "dataset and assert bit-exactness vs the tensor "
                         "program and the kernel backend")
    ap.add_argument("--report", action="store_true",
                    help="score the campaign against paper Tables I/II "
                         "(OUT/sweep_report.json + OUT/REPORT.md)")
    ap.add_argument("--fault-report", action="store_true",
                    help="run the stuck-at robustness campaign on every "
                         "dataset's best-under-loss point (DESIGN.md §17): "
                         "OUT/<dataset>/fault_report.json + a robustness-"
                         "vs-area section in REPORT.md")
    ap.add_argument("--max-loss", type=float, default=0.01)
    args = ap.parse_args(argv)

    names = (sorted(DATASET_SPECS) if args.datasets == "all"
             else [n.strip() for n in args.datasets.split(",") if n.strip()])
    mlp_names = [n.strip() for n in args.mlp_datasets.split(",") if n.strip()]
    unknown = [n for n in names + mlp_names if n not in DATASET_SPECS]
    if unknown:
        ap.error(f"unknown datasets: {unknown}; options: "
                 f"{sorted(DATASET_SPECS)}")
    if args.compilation_cache:
        from repro.runtime import compile_cache
        compile_cache.enable(args.compilation_cache)

    kind = "tree" if args.trees <= 1 else f"forest[{args.trees}]"
    extra = (f" + {len(mlp_names)} printed-MLP datasets" if mlp_names else "")
    print(f"== sweep: {len(names)} datasets, {kind} per dataset{extra}, "
          f"pop={args.pop} gens={args.gens} ==")
    problems = sweep_mod.build_problems(names, n_trees=args.trees,
                                        verbose=True,
                                        mlp_datasets=mlp_names,
                                        n_hidden=args.hidden)

    cfg = sweep_mod.SweepConfig(
        pop_size=args.pop, n_generations=args.gens, seed=args.seed,
        vmapped=not args.serial, max_buckets=args.max_buckets,
        mesh=args.mesh, out_dir=args.out, emit_rtl=args.emit_rtl,
        verify_rtl=args.verify_rtl)
    sweep = sweep_mod.run_sweep(problems, cfg)

    for i, run in enumerate(sweep.bucket_runs):
        d = run.bucket.dims
        if run.bucket.family == "tree":
            dims_s = f"(N={d[0]}, L={d[1]}, C={d[2]}, F={d[3]}, B={d[4]})"
        else:
            dims_s = f"(H={d[0]}, C={d[1]}, F={d[2]}, B={d[3]})"
        print(f"bucket {i}: [{run.bucket.family}] "
              f"{', '.join(run.bucket.names)} -> padded {dims_s}, "
              f"{run.n_dispatches} dispatches, {run.wall_s:.1f}s")
    print(f"campaign: {sweep.n_dispatches} dispatches over "
          f"{len(sweep.bucket_runs)} buckets (serial per-dataset baseline: "
          f"{sweep.serial_baseline_dispatches()}), wall {sweep.wall_s:.1f}s")

    for name in sorted(sweep.results):
        result = sweep.results[name]
        problem = problems[name]
        best = result.best_under_loss(args.max_loss)
        if best is None:
            line = f"no design within {args.max_loss:.0%} loss"
        else:
            o, _ = best
            a_mm2 = float(o[1]) * problem.exact_area_mm2
            line = (f"@<={args.max_loss:.0%} loss: {1 / max(float(o[1]), 1e-9):.2f}x "
                    f"smaller, {a_mm2:.1f}mm^2, "
                    f"{area.power_mw(a_mm2):.2f}mW")
        print(f"  {name}: exact_acc={problem.exact_accuracy:.3f} "
              f"pareto={len(result.pareto_objs)} pts; {line}")
    if args.verify_rtl:
        n_pts = sum(len(r.pareto_objs) for r in sweep.results.values())
        print(f"RTL verified: {n_pts} pareto points across {len(problems)} "
              f"problems (netlist sim == tensor predict == kernel route)")

    if args.fault_report:
        import os

        from repro.search import robustness

        print(f"== fault campaign: best point per dataset, defect_rate="
              f"{robustness.DEFAULT_DEFECT_RATE:.0%}, "
              f"{robustness.DEFAULT_TRIALS} MC trials ==")
        for name in sorted(sweep.results):
            pareto_path = os.path.join(args.out, name, "pareto.json")
            if not os.path.exists(pareto_path):
                continue
            artifact = search.load_pareto_artifact(pareto_path)
            problem = problems[name]
            x8 = np.asarray(problem.x8)
            y = np.asarray(problem.y)
            try:
                payload = robustness.run_campaign(
                    artifact, x8, y, source=pareto_path,
                    dataset=name, point="best", max_loss=args.max_loss)
            except ValueError as e:   # e.g. no point within the budget
                print(f"  {name}: skipped ({e})")
                continue
            out_path = robustness.write_fault_report(
                payload, os.path.join(args.out, name, "fault_report.json"))
            row = payload["points"][0]
            print(f"  {name}: point {row['point']} "
                  f"({row['n_sites']} sites) baseline "
                  f"{row['baseline_accuracy']:.4f} -> 1-fault worst "
                  f"{row['single_fault']['worst_accuracy']:.4f}, "
                  f"MC {row['monte_carlo']['expected_accuracy']:.4f} "
                  f"-> {out_path}")

    if args.report:
        meta = {"datasets": args.datasets, "trees": args.trees,
                "pop": args.pop, "gens": args.gens, "seed": args.seed,
                "mode": "serial" if args.serial else "vmapped"}
        if mlp_names:
            meta["mlp_datasets"] = args.mlp_datasets
            meta["hidden"] = args.hidden
        json_path, md_path = sweep_mod.write_sweep_report(
            sweep, problems, args.out, meta=meta, max_loss=args.max_loss)
        print(f"report: {json_path} + {md_path}")
    print(f"artifacts: {args.out}/<dataset>/pareto.json")


def serve_main(argv=None) -> None:
    """`python -m repro.search serve`: serve a pareto.json design under load.

    Loads a `pareto.json` point (the artifact is self-contained —
    DESIGN.md §14), stands up `runtime.classify.ClassifyServer`, and
    serves the recorded dataset's test split in request batches: reports
    throughput and the served accuracy, asserts it matches the artifact's
    recorded per-point accuracy, and with `--verify-netlist` additionally
    asserts every served prediction bit-exact against the gate-level
    netlist simulator (the serving oracle triangle).
    """
    import sys
    import time

    from repro.core import netlist
    from repro.runtime.classify import BACKENDS as SERVE_BACKENDS
    from repro.runtime.classify import ClassifyServer

    ap = argparse.ArgumentParser(prog="python -m repro.search serve")
    ap.add_argument("--pareto", required=True,
                    help="path to a pareto.json written by run_search/sweep")
    ap.add_argument("--point", default="best",
                    help="pareto point index, or 'best' = smallest area "
                         "within --max-loss")
    ap.add_argument("--max-loss", type=float, default=0.01)
    ap.add_argument("--dataset", default=None,
                    help="dataset whose test split to serve (default: the "
                         "artifact's recorded dataset)")
    ap.add_argument("--backend", default="kernel", choices=SERVE_BACKENDS,
                    help="kernel = fused Pallas inference; reference = "
                         "pure-jnp predict_votes dataflow")
    ap.add_argument("--batch", type=int, default=64,
                    help="request size: the test split is served in batches "
                         "of this many feature vectors")
    ap.add_argument("--max-batch", type=int, default=1024,
                    help="largest power-of-two batch bucket")
    ap.add_argument("--repeats", type=int, default=1,
                    help="serve the test split this many times (throughput "
                         "measurement)")
    ap.add_argument("--verify-netlist", action="store_true",
                    help="simulate the served design's gate-level netlist "
                         "over every served batch and assert bit-exactness")
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory")
    args = ap.parse_args(argv)
    if args.compilation_cache:
        from repro.runtime import compile_cache
        compile_cache.enable(args.compilation_cache)

    artifact = _load_artifact_or_exit(args.pareto)
    point = args.point if args.point == "best" else int(args.point)
    server = ClassifyServer.from_artifact(
        artifact, point=point, max_loss=args.max_loss,
        backend=args.backend, max_batch=args.max_batch)
    idx = server.point_index
    pt = artifact.points[idx]
    family = getattr(artifact, "family", "tree")
    if family == "mlp":
        design = (f"printed MLP {artifact.n_features}-"
                  f"{artifact.n_hidden}-{artifact.n_classes}")
    else:
        design = (f"{artifact.n_trees} tree(s), "
                  f"{artifact.n_comparators} comparators")
    print(f"== serving {args.pareto} point {idx}: {design}, "
          f"acc_loss={pt['acc_loss']:+.4f} "
          f"norm_area={pt['norm_area']:.3f} backend={args.backend} ==")

    dataset = args.dataset or artifact.dataset
    if dataset is None:
        ap.error("--dataset required: this artifact predates the recorded "
                 "'dataset' label")
    ds = load_dataset(dataset)
    codes = server.featurize(ds.x_test)
    y = ds.y_test.astype(np.int64)

    circuit = None
    if args.verify_netlist:
        from repro.families import get_family
        circuit = get_family(family).build_point_circuit(artifact, idx)

    n = codes.shape[0]
    preds = np.zeros(n, np.int64)
    n_requests = 0
    n_verified = 0
    t0 = time.perf_counter()
    for _ in range(max(1, args.repeats)):
        for lo in range(0, n, args.batch):
            chunk = codes[lo:lo + args.batch]
            out = server.classify_codes(chunk)
            preds[lo:lo + args.batch] = out
            n_requests += 1
            if circuit is not None:
                sim = np.asarray(netlist.simulate(circuit, chunk))
                if not np.array_equal(sim, out):
                    print(f"FAIL: request at rows [{lo}, {lo + len(out)}) "
                          f"diverges from the netlist oracle on "
                          f"{int((sim != out).sum())} rows")
                    sys.exit(1)
                n_verified += len(out)
    wall = time.perf_counter() - t0

    acc = float((preds == y).mean())
    recorded = artifact.point_accuracy(idx)
    total = n * max(1, args.repeats)
    print(f"served {total} samples in {n_requests} requests "
          f"({wall:.3f}s, {total / max(wall, 1e-9):,.0f} samples/s, "
          f"{n_requests / max(wall, 1e-9):,.0f} requests/s)")
    print(f"buckets compiled: {server.compiled_buckets()} "
          f"(steps per bucket: {server.stats.steps_per_bucket})")
    print(f"served accuracy on {dataset} test split: {acc:.4f} "
          f"(artifact recorded {recorded:.4f})")
    if abs(acc - recorded) > 1e-6:
        print(f"FAIL: served accuracy {acc:.6f} != recorded "
              f"{recorded:.6f} — the loaded design does not reproduce "
              f"the searched point")
        sys.exit(1)
    if circuit is not None:
        print(f"netlist oracle: {n_verified} served predictions bit-exact "
              f"vs the gate-level simulation")


def faults_main(argv=None) -> None:
    """`python -m repro.search faults`: stuck-at robustness campaign.

    Loads a `pareto.json`, rebuilds the selected point(s)' gate-level
    circuits through the family registry, and runs the DESIGN.md §17
    campaign — exhaustive single stuck-at over every fault site,
    Monte-Carlo defect draws under fixed PRNG keys, and the critical-gate
    ranking — writing a validated `fault_report.json` next to the artifact
    (or to --out).
    """
    import os

    from repro.datasets import quantize_u8
    from repro.search import robustness

    ap = argparse.ArgumentParser(prog="python -m repro.search faults")
    ap.add_argument("--pareto", required=True,
                    help="path to a pareto.json written by run_search/sweep")
    ap.add_argument("--point", default="all",
                    help="pareto point index, 'best' = smallest area within "
                         "--max-loss, or 'all' (default)")
    ap.add_argument("--max-loss", type=float, default=0.01)
    ap.add_argument("--dataset", default=None,
                    help="dataset whose test split drives the campaign "
                         "(default: the artifact's recorded dataset)")
    ap.add_argument("--defect-rate", type=float,
                    default=robustness.DEFAULT_DEFECT_RATE,
                    help="Monte-Carlo iid per-site defect probability")
    ap.add_argument("--trials", type=int, default=robustness.DEFAULT_TRIALS,
                    help="Monte-Carlo defect draws per point")
    ap.add_argument("--mc-seed", type=int,
                    default=robustness.DEFAULT_MC_SEED,
                    help="PRNG seed for the Monte-Carlo masks (fixed seed "
                         "-> bit-reproducible report)")
    ap.add_argument("--top-k", type=int, default=robustness.DEFAULT_TOP_K,
                    help="critical gates reported per point")
    ap.add_argument("--chunk", type=int, default=None,
                    help="fault lanes per vmapped dispatch (default: "
                         "auto-sized to the memory budget)")
    ap.add_argument("--out", default=None,
                    help="fault_report.json path (default: next to --pareto)")
    args = ap.parse_args(argv)

    artifact = _load_artifact_or_exit(args.pareto)
    dataset = args.dataset or artifact.dataset
    if dataset is None:
        ap.error("--dataset required: this artifact predates the recorded "
                 "'dataset' label")
    ds_name = dataset.removesuffix("_mlp")
    if ds_name not in DATASET_SPECS:
        ap.error(f"unknown dataset {ds_name!r}; options: "
                 f"{sorted(DATASET_SPECS)}")
    ds = load_dataset(ds_name)
    x8 = quantize_u8(ds.x_test)
    y = np.asarray(ds.y_test, np.int64)

    family = getattr(artifact, "family", "tree")
    print(f"== fault campaign: {args.pareto} [{family}] on {ds_name} "
          f"({x8.shape[0]} test vectors), point={args.point}, "
          f"defect_rate={args.defect_rate:.2%}, {args.trials} MC trials, "
          f"seed={args.mc_seed} ==")
    try:
        payload = robustness.run_campaign(
            artifact, x8, y, source=args.pareto, dataset=dataset,
            point=args.point, max_loss=args.max_loss,
            defect_rate=args.defect_rate, n_trials=args.trials,
            seed=args.mc_seed, top_k=args.top_k, chunk=args.chunk,
            verbose=True)
    except ValueError as e:
        import sys

        print(f"error: fault campaign: {e}", file=sys.stderr)
        raise SystemExit(2)
    out = args.out or os.path.join(
        os.path.dirname(args.pareto) or ".", "fault_report.json")
    robustness.write_fault_report(payload, out)
    worst = min(p["single_fault"]["worst_accuracy"]
                for p in payload["points"])
    print(f"campaign: {len(payload['points'])} point(s), worst single-fault "
          f"accuracy {worst:.4f}; report: {out}")


def main(argv=None) -> None:
    import sys

    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "sweep":
        return sweep_main(argv[1:])
    if argv and argv[0] == "serve":
        return serve_main(argv[1:])
    if argv and argv[0] == "faults":
        return faults_main(argv[1:])
    ap = argparse.ArgumentParser(prog="python -m repro.search")
    ap.add_argument("--dataset", default="seeds",
                    choices=sorted(DATASET_SPECS))
    ap.add_argument("--family", default="tree", choices=("tree", "mlp"),
                    help="classifier family to search (DESIGN.md §15): "
                         "bespoke decision trees/forests, or integer-weight "
                         "printed MLPs")
    ap.add_argument("--trees", type=int, default=1,
                    help="tree family: 1 = single bespoke DT; K>1 = "
                         "bootstrap forest with a joint 3*sum(N_k)+1-gene "
                         "chromosome (DESIGN.md §16)")
    ap.add_argument("--hidden", type=int, default=16,
                    help="mlp family: hidden-layer width")
    ap.add_argument("--backend", default="reference",
                    choices=list(search.BACKENDS))
    ap.add_argument("--mesh", default=None,
                    help="device mesh spec (DESIGN.md §13): 'N' or 'auto' "
                         "shards the population axis over N / all devices "
                         "(islands: the ring size); default: single device")
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory")
    ap.add_argument("--block-p", type=int, default=8,
                    help="kernel backend: chromosomes per fused-fitness grid "
                         "cell (population-axis tile, DESIGN.md §12)")
    ap.add_argument("--pop", type=int, default=64)
    ap.add_argument("--gens", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="artifact directory")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="generations between checkpoint saves (0 = off); "
                         "also the lax.scan chunk length, so one interval = "
                         "one device dispatch")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint under "
                         "OUT/ckpt (all backends, islands included)")
    ap.add_argument("--migrate-every", type=int, default=5,
                    help="islands backend: generations between ring "
                         "migrations (checkpoints land on round boundaries)")
    ap.add_argument("--n-migrate", type=int, default=4,
                    help="islands backend: elites migrated per round")
    ap.add_argument("--max-loss", type=float, default=0.01)
    ap.add_argument("--emit-rtl", action="store_true",
                    help="write every pareto point's Verilog under OUT/rtl/ "
                         "(single trees and forests alike)")
    ap.add_argument("--verify-rtl", action="store_true",
                    help="netlist-simulate every pareto point over the full "
                         "test set and assert bit-exactness vs the tensor "
                         "program and the kernel backend")
    args = ap.parse_args(argv)
    if (args.emit_rtl or args.verify_rtl) and not args.out:
        ap.error("--emit-rtl/--verify-rtl require --out")
    if args.compilation_cache:
        from repro.runtime import compile_cache
        compile_cache.enable(args.compilation_cache)

    from repro.families import get_family

    fam = get_family(args.family)
    if args.family == "mlp":
        problem = fam.build_problem(args.dataset, n_hidden=args.hidden)
        kind = f"mlp[h={args.hidden}]"
    else:
        problem = fam.build_problem(args.dataset, n_trees=args.trees)
        kind = "tree" if args.trees <= 1 else f"forest[{args.trees}]"

    print(f"== {args.dataset} {fam.describe(problem)} "
          f"exact_area={problem.exact_area_mm2:.1f}mm^2 "
          f"power={area.power_mw(problem.exact_area_mm2):.2f}mW ==")

    cfg = search.SearchConfig(
        backend=args.backend, block_p=args.block_p, pop_size=args.pop,
        n_generations=args.gens, seed=args.seed, mesh=args.mesh,
        dataset=args.dataset, out_dir=args.out,
        checkpoint_every=args.checkpoint_every, resume=args.resume,
        migrate_every=args.migrate_every, n_migrate=args.n_migrate,
        emit_rtl=args.emit_rtl, verify_rtl=args.verify_rtl,
    )
    print(f"== run_search backend={cfg.backend} pop={cfg.pop_size} "
          f"gens={cfg.n_generations} ==")
    result = search.run_search(problem, cfg)

    print(f"search wall time: {result.wall_s:.1f}s "
          f"({result.n_evaluations} chromosome evaluations, "
          f"{result.n_dispatches} device dispatches)")
    print("pareto front (acc_loss, normalized area):")
    for o in result.pareto_objs:
        print(f"  {o[0]:+.4f}  {o[1]:.3f}  ({1 / max(o[1], 1e-9):.2f}x smaller)")

    best = result.best_under_loss(args.max_loss)
    if best is None:
        print(f"no design within {args.max_loss:.0%} accuracy loss")
    else:
        o, genes = best
        a_mm2 = float(o[1]) * problem.exact_area_mm2
        print(f"\nselected @<={args.max_loss:.0%} loss: area={a_mm2:.1f}mm^2 "
              f"({1 / o[1]:.2f}x), power={area.power_mw(a_mm2):.2f}mW "
              f"{'< 3mW: printed-battery OK' if area.power_mw(a_mm2) < 3 else ''}")

    if args.out:
        import json
        import os

        import jax.numpy as jnp
        from repro.core import rtl
        if best is not None:
            if args.family == "mlp":
                from repro.core import netlist
                from repro.families import printed_mlp as pm_mod

                bits_a, margin_a = pm_mod.decode_design(np.asarray(genes))
                h = problem.n_hidden
                w1 = pm_mod.effective_weights(problem.w1_master,
                                              bits_a[:h], margin_a[:h])
                w2 = pm_mod.effective_weights(problem.w2_master,
                                              bits_a[h:], margin_a[h:])
                circuit = netlist.build_mlp_circuit(
                    w1, w2, problem.shift, problem.n_classes)
                verilog = rtl.emit_circuit_verilog(
                    circuit, module_name=f"printed_mlp_{args.dataset}")
            else:
                # effective (post-truncation) design: lowering it with
                # trunc=None is identical to lowering the pre-truncation
                # design with its trunc vector (DESIGN.md §16)
                bits, t_int, vote_cap = search.decode_chromosome(
                    problem, jnp.asarray(genes))
                vote_adder = ("approx" if np.isfinite(float(vote_cap))
                              else "exact")
                verilog = rtl.emit_design(search.problem_ptrees(problem),
                                          np.asarray(bits),
                                          np.asarray(t_int),
                                          problem.n_classes,
                                          vote_adder=vote_adder)
            path = os.path.join(args.out, f"bespoke_{args.dataset}.v")
            with open(path, "w") as f:
                f.write(verilog)
            print(f"bespoke {kind} RTL written to {path} "
                  f"({len(verilog.splitlines())} lines)")

        with open(os.path.join(args.out, "pareto.json")) as f:
            artifact = json.load(f)
        pts = artifact["pareto"]
        if args.emit_rtl:
            print(f"per-pareto-point RTL: {args.out}/rtl/ "
                  f"({len(pts)} designs: "
                  f"{', '.join(p['rtl'] for p in pts[:3])}"
                  f"{', ...' if len(pts) > 3 else ''})")
        if args.verify_rtl:
            oracle = ("tensor predict" if args.family == "mlp"
                      else "predict_votes")
            print(f"RTL verified: {len(pts)}/{len(pts)} pareto points "
                  f"bit-exact over {problem.x8.shape[0]} test samples "
                  f"(netlist sim == {oracle} == kernel backend)")
        gaps = search.netlist_area_ratios(pts)
        if gaps:
            print(f"estimated-vs-netlist area: netlist/LUT ratio "
                  f"min {min(gaps):.2f} / mean {sum(gaps) / len(gaps):.2f} / "
                  f"max {max(gaps):.2f} across {len(gaps)} points")
        print(f"pareto artifact: {args.out}/pareto.json")


if __name__ == "__main__":
    main()
