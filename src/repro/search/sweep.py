"""Batched full-suite campaign engine (DESIGN.md §11).

The paper's evaluation is one uniform campaign over ten UCI datasets
(Tables I/II, Figs. 4-5), but `run_search` drives one `SearchProblem` at a
time — ten sequential GA runs, ten times the dispatch overhead, and a CI
that only ever exercised two of the ten scenarios. This module runs the
whole campaign as a handful of vmapped programs:

  1. **Pad** every problem's operands up to bucket-boundary shapes
     (`pad_problem`): comparator/leaf/class/feature/sample axes are rounded
     up to powers of two and filled with *inert* genes — padded comparators
     carry zero path entries, padded leaves an unreachable satisfaction
     target, padded samples the impossible label -1 — so the padded
     objectives reproduce the unpadded semantics (predictions bit-exact,
     objectives equal to float rounding; the inertness itself is exact:
     changing pad genes never changes an objective bit).
  2. **Bucket** problems sharing a padded shape (`plan_buckets`), greedily
     merging the cheapest pairs until at most `max_buckets` remain, so the
     whole 10-dataset suite compiles a handful of programs instead of ten.
  3. **Stack & vmap**: each bucket's operands stack on a leading problem
     axis and `nsga2.make_batched_init` / `make_batched_chunk` (§9's
     chunked scan, vmapped) advance every member with ONE dispatch per
     stage — `SweepResult.n_dispatches` is 2 per bucket vs 2 per dataset
     for the serial loop.

The per-problem serial loop (`vmapped=False`) is kept as the bit-exact
oracle: it runs the SAME padded problems through the un-vmapped
`nsga2.make_chunk`, and tests assert the final populations are
bit-identical array-for-array. Exactness under vmap holds because every
cross-lane reduction is integer-valued in f32: accuracy sums 0/1 matches,
and area sums the integer-quanta LUT (`area.build_area_unit_lut`), scaling
to mm^2 only at the end.

Per-dataset artifacts reuse the single-run pipeline unchanged: each
problem's final population is unpadded (real gene columns sliced back out)
and handed to `engine.write_pareto_artifact`, so `pareto.json`, `--emit-rtl`
and `--verify-rtl` behave exactly as in `run_search`. `write_sweep_report`
then scores every dataset against the paper's published Tables I/II
(`repro.datasets.paper_refs`).

CLI: ``python -m repro.search sweep --datasets all --report``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import area as area_mod
from repro.core import nsga2, quant
from repro.search import engine as _engine
from repro.search.problem import SearchProblem

GRANULE = 8            # minimum padded extent per axis
DEFAULT_MAX_BUCKETS = 6


# ---------------------------------------------------------------------------
# padding
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PaddedProblem:
    """One `SearchProblem` padded to bucket-boundary shapes with inert genes.

    The padding is masked so a padded evaluation means the same thing as the
    unpadded one:

      - padded **comparators** gather feature 0 and carry all-zero `path`
        columns, so their decisions never reach a leaf score; their rows are
        masked out of the area sum (`comp_valid`);
      - padded **leaves** carry `path_len = 1` over an all-zero path row —
        a satisfaction target of 1 that a zero score can never meet — so
        they never vote;
      - padded **classes** receive votes from no leaf, and first-max argmax
        cannot select them because every real sample collects >= 1 real
        vote (exactly one leaf per real tree fires);
      - padded **samples** carry label -1, which no prediction (>= 0) can
        match; accuracy divides by the real sample count `n_valid`;
      - padded **features** are zero columns no real comparator gathers.

    `area_lut_units` holds the integer-quanta LUT: the masked population
    area sum stays integer-valued in f32, hence bit-identical under any
    vmap tiling (DESIGN.md §11); `AREA_QUANTUM_MM2` scales once at the end.
    """

    feature: jnp.ndarray         # (Np,) int32
    threshold: jnp.ndarray       # (Np,) float32
    path: jnp.ndarray            # (Lp, Np) int8
    path_len: jnp.ndarray        # (Lp,) int32
    n_neg: jnp.ndarray           # (Lp,) int32
    leaf_onehot: jnp.ndarray     # (Lp, Cp) float32
    x8: jnp.ndarray              # (Bp, Fp) int32
    x_sel: jnp.ndarray           # (Bp, Np) int32 hoisted x8[:, feature]
                                 #   (chromosome-invariant, DESIGN.md §12)
    y: jnp.ndarray               # (Bp,) int32 (-1 on padded rows)
    comp_valid: jnp.ndarray      # (Np,) bool
    n_valid: jnp.ndarray         # () float32 — real test-sample count
    area_lut_units: jnp.ndarray  # integer-quanta area LUT (f32-exact)
    lut_offsets: jnp.ndarray     # (MAX_BITS+1,) int32
    overhead_mm2: jnp.ndarray    # () float32
    exact_area_mm2: jnp.ndarray  # () float32
    exact_accuracy: jnp.ndarray  # () float32
    # integer vote-adder quanta (DESIGN.md §16): exact popcount tree vs
    # saturating OR-tree. Integer-valued f32 like the LUT rows, so the
    # area sum stays vmap-order invariant. Both 0 for single trees.
    vote_units_exact: jnp.ndarray = dataclasses.field(
        default_factory=lambda: jnp.float32(0.0))
    vote_units_approx: jnp.ndarray = dataclasses.field(
        default_factory=lambda: jnp.float32(0.0))

    @property
    def n_genes(self) -> int:
        # cross-layer layout (DESIGN.md §16): 3 genes per comparator slot
        # plus the trailing forest-level vote-adder gene
        return 3 * int(self.feature.shape[0]) + 1


jax.tree_util.register_pytree_node(
    PaddedProblem,
    lambda p: (tuple(getattr(p, f.name)
                     for f in dataclasses.fields(PaddedProblem)), None),
    lambda _, children: PaddedProblem(*children),
)


def round_up_pow2(n: int, granule: int = GRANULE) -> int:
    """Next power of two >= max(n, granule): the bucket boundary per axis.

    Shared by the sweep's shape buckets and the serving runtime's request
    micro-batching (`runtime.classify`, DESIGN.md §14) — one rounding rule
    means a served batch and a sweep problem land on the same grid of
    compiled shapes.
    """
    n = max(int(n), int(granule))
    p = 1
    while p < n:
        p <<= 1
    return p


_round_up_pow2 = round_up_pow2


def problem_dims(problem: SearchProblem) -> tuple[int, int, int, int, int]:
    """Real (unpadded) operand extents: (N, L, C, F, B)."""
    return (problem.n_comparators, problem.n_leaves, problem.n_classes,
            problem.n_features, int(problem.x8.shape[0]))


def pad_problem(problem: SearchProblem,
                dims: tuple[int, int, int, int, int]) -> PaddedProblem:
    """Pad a `SearchProblem` to `dims` = (Np, Lp, Cp, Fp, Bp) (see class doc)."""
    np_, lp, cp, fp, bp = dims
    n, l, c, f, b = problem_dims(problem)
    if not (np_ >= n and lp >= l and cp >= c and fp >= f and bp >= b):
        raise ValueError(f"padded dims {dims} smaller than problem dims "
                         f"{(n, l, c, f, b)}")

    feature = np.zeros(np_, np.int32)
    feature[:n] = np.asarray(problem.feature)
    threshold = np.full(np_, 0.5, np.float32)
    threshold[:n] = np.asarray(problem.threshold)
    path = np.zeros((lp, np_), np.int8)
    path[:l, :n] = np.asarray(problem.path)
    path_len = np.ones(lp, np.int32)              # unreachable target for pads
    path_len[:l] = np.asarray(problem.path_len)
    n_neg = np.zeros(lp, np.int32)
    n_neg[:l] = np.asarray(problem.n_neg)
    leaf_onehot = np.zeros((lp, cp), np.float32)  # padded leaves never vote
    leaf_onehot[np.arange(l), np.asarray(problem.leaf_class)] = 1.0
    x8 = np.zeros((bp, fp), np.int32)
    x8[:b, :f] = np.asarray(problem.x8)
    y = np.full(bp, -1, np.int32)
    y[:b] = np.asarray(problem.y)
    comp_valid = np.zeros(np_, bool)
    comp_valid[:n] = True
    lut_units, offsets = area_mod.build_area_unit_lut()

    return PaddedProblem(
        feature=jnp.asarray(feature),
        threshold=jnp.asarray(threshold),
        path=jnp.asarray(path),
        path_len=jnp.asarray(path_len),
        n_neg=jnp.asarray(n_neg),
        leaf_onehot=jnp.asarray(leaf_onehot),
        x8=jnp.asarray(x8),
        x_sel=jnp.asarray(x8[:, feature]),
        y=jnp.asarray(y),
        comp_valid=jnp.asarray(comp_valid),
        n_valid=jnp.float32(b),
        area_lut_units=jnp.asarray(lut_units),
        lut_offsets=jnp.asarray(offsets),
        overhead_mm2=jnp.float32(problem.overhead_mm2),
        exact_area_mm2=jnp.float32(problem.exact_area_mm2),
        exact_accuracy=jnp.float32(problem.exact_accuracy),
        vote_units_exact=jnp.float32(area_mod.vote_adder_units(
            problem.n_trees, problem.n_classes, approx=False)),
        vote_units_approx=jnp.float32(area_mod.vote_adder_units(
            problem.n_trees, problem.n_classes, approx=True)),
    )


# ---------------------------------------------------------------------------
# padded evaluation (mirrors search.problem's reference primitives)
# ---------------------------------------------------------------------------

def _padded_decode(pp: PaddedProblem, genes):
    """ONE gene decode shared by predictions and the area term (§12).

    Returns the EFFECTIVE (bits, t_sub, vote_cap): comparator truncation is
    folded into the operands exactly as in `search.decode_chromosome`
    (DESIGN.md §16), so the padded dataflow prices and evaluates the same
    approximate cells the netlist lowers."""
    bits, margin, trunc, vote = quant.decode_tree_genes(genes)
    t_int = quant.threshold_to_int(pp.threshold, bits)
    t_sub = quant.substitute(t_int, margin, bits)
    vote_cap = jnp.where(vote > 0, jnp.float32(1.0), jnp.float32(jnp.inf))
    return bits - trunc, jnp.right_shift(t_sub, trunc), vote_cap


def _padded_predict_decoded(pp: PaddedProblem, bits, t_sub, vote_cap):
    """(Bp,) voted class from an already-decoded chromosome."""
    x_p = quant.inputs_at_precision(pp.x_sel, bits)
    d = (x_p > t_sub[None, :]).astype(jnp.float32)
    score = d @ pp.path.T.astype(jnp.float32)
    target = (pp.path_len - pp.n_neg).astype(jnp.float32)
    sat = (score == target[None, :]).astype(jnp.float32)
    votes = sat @ pp.leaf_onehot
    # saturating (approximate) vote adder: +inf cap = exact f32 no-op
    votes = jnp.minimum(votes, vote_cap)
    return jnp.argmax(votes, axis=1)


def padded_predict(pp: PaddedProblem, genes):
    """(Bp,) voted class per sample — §2's dataflow on padded operands.

    On the real sample rows this is bit-exact vs `problem.predict_votes`
    with the real gene slice (tests pin it): every padded contribution is
    structurally zero, and all reductions are integer-valued in f32. The
    feature gather is hoisted onto the context (`pp.x_sel`, §12), so the
    per-chromosome work starts at the precision shift.
    """
    bits, t_sub, vote_cap = _padded_decode(pp, genes)
    return _padded_predict_decoded(pp, bits, t_sub, vote_cap)


def padded_objectives(pp: PaddedProblem, genes):
    """(accuracy loss, normalized area) for one padded chromosome (3*Np+1,).

    Matches `search.objectives` on the real slice up to float rounding (the
    area term sums integer quanta instead of f32 mm^2 rows — that is what
    buys vmap-order invariance); the *inertness* of pad genes is exact.
    One shared decode feeds both objectives (§12). The vote-adder term
    selects between the two integer unit counts (DESIGN.md §16), so the
    sum stays integer-valued in f32.
    """
    bits, t_sub, vote_cap = _padded_decode(pp, genes)
    pred = _padded_predict_decoded(pp, bits, t_sub, vote_cap)
    acc = jnp.sum((pred == pp.y).astype(jnp.float32)) / pp.n_valid

    idx = pp.lut_offsets[bits] + t_sub
    units = jnp.where(pp.comp_valid, pp.area_lut_units[idx], 0.0).sum()
    units = units + jnp.where(jnp.isfinite(vote_cap),
                              pp.vote_units_approx, pp.vote_units_exact)
    area = units * area_mod.AREA_QUANTUM_MM2 + pp.overhead_mm2
    return jnp.stack([pp.exact_accuracy - acc, area / pp.exact_area_mm2])


def population_objectives(pp: PaddedProblem, pop):
    """(P, 3*Np+1) genes -> (P, 2) objectives — the `fitness_from_ctx` handed
    to `nsga2.make_batched_init` / `make_batched_chunk`."""
    return jax.vmap(lambda g: padded_objectives(pp, g))(pop)


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Bucket:
    """A set of problems of ONE family sharing a padded operand shape.

    Tree buckets carry dims (N, L, C, F, B); MLP buckets (H, C, F, B).
    Problems of different families never share a bucket: their padded
    pytrees are different types and cannot stack (DESIGN.md §15).
    """
    names: tuple[str, ...]
    dims: tuple[int, ...]
    family: str = "tree"

    def dims_dict(self) -> dict:
        keys = (("n_comparators", "n_leaves", "n_classes", "n_features",
                 "n_samples") if self.family == "tree"
                else ("n_hidden", "n_classes", "n_features", "n_samples"))
        return dict(zip(keys, self.dims))


def _eval_cost(dims: tuple[int, ...]) -> float:
    """Dominant per-chromosome FLOP terms of §2's dataflow at padded shapes."""
    np_, lp, cp, fp, bp = dims
    return float(bp) * (np_ + np_ * lp + lp * cp)


def plan_buckets(problems: dict, *,
                 granule: int = GRANULE,
                 max_buckets: int = DEFAULT_MAX_BUCKETS) -> list[Bucket]:
    """Group problems by (family, power-of-two-rounded operand shape), then
    greedily merge the SAME-FAMILY pair costing the least extra padded
    compute until at most `max_buckets` buckets remain (a mixed-family
    campaign may exceed `max_buckets` when no intra-family merge is left —
    cross-family stacks cannot exist). Deterministic given the problem dict
    (iteration is name-sorted); merged dims are elementwise maxima, so they
    stay powers of two."""
    from repro.families import family_of, get_family

    if max_buckets < 1:
        raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
    groups: dict[tuple, list[str]] = {}
    for name in sorted(problems):
        fam = family_of(problems[name])
        dims = tuple(_round_up_pow2(d, granule)
                     for d in fam.problem_dims(problems[name]))
        groups.setdefault((fam.name, dims), []).append(name)
    buckets = [Bucket(names=tuple(v), dims=k[1], family=k[0])
               for k, v in sorted(groups.items())]

    while len(buckets) > max_buckets:
        best = None
        for i in range(len(buckets)):
            for j in range(i + 1, len(buckets)):
                bi, bj = buckets[i], buckets[j]
                if bi.family != bj.family:
                    continue
                cost = get_family(bi.family).eval_cost
                merged = tuple(max(a, b) for a, b in zip(bi.dims, bj.dims))
                extra = (cost(merged) * (len(bi.names) + len(bj.names))
                         - cost(bi.dims) * len(bi.names)
                         - cost(bj.dims) * len(bj.names))
                if best is None or extra < best[0]:
                    best = (extra, i, j, merged)
        if best is None:  # only cross-family pairs left: cannot merge further
            break
        _, i, j, merged = best
        buckets[i] = Bucket(names=tuple(sorted(buckets[i].names
                                               + buckets[j].names)),
                            dims=merged, family=buckets[i].family)
        del buckets[j]
    return sorted(buckets, key=lambda b: b.names)


def stack_padded(padded: list[PaddedProblem]) -> PaddedProblem:
    """Stack same-shape PaddedProblems on a leading problem axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)


# ---------------------------------------------------------------------------
# the campaign driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepConfig:
    pop_size: int = 64
    n_generations: int = 40
    seed: int = 0
    vmapped: bool = True            # False = the serial bit-exact oracle
    granule: int = GRANULE
    max_buckets: int = DEFAULT_MAX_BUCKETS
    # mesh spec for `launch.mesh.make_search_mesh(axes=("bucket", "pop"))`
    # (DESIGN.md §13): "2x4" spreads each bucket's problem stack over 2
    # bucket shards and every population over 4 shards; "4"/"auto" put all
    # devices on the population axis. None = the single-device vmapped path.
    # Requires vmapped=True (the serial loop is the mesh-free oracle).
    mesh: str | None = None
    out_dir: str | None = None      # per-dataset artifacts under OUT/<name>/
    emit_rtl: bool = False
    verify_rtl: bool = False


@dataclasses.dataclass
class BucketRun:
    bucket: Bucket
    n_dispatches: int
    wall_s: float


@dataclasses.dataclass
class SweepResult:
    results: dict[str, "_engine.SearchResult"]
    bucket_runs: list[BucketRun]
    wall_s: float

    @property
    def n_dispatches(self) -> int:
        """Generation-loop dispatches summed over buckets — the acceptance
        number: 2 per bucket (init + one chunk) vs 2 per dataset serially."""
        return sum(r.n_dispatches for r in self.bucket_runs)

    def serial_baseline_dispatches(self) -> int:
        """What the same campaign costs as per-dataset `run_search` calls."""
        return 2 * len(self.results)


def _problem_keys(names_sorted: list[str], seed: int):
    """Per-problem PRNG keys: dataset i of the name-sorted campaign always
    folds in i, so the key never depends on the bucket plan. (The padded
    chromosome length IS part of the plan — GA draws are shape-dependent —
    so results are reproducible per (seed, plan), and vmapped-vs-serial
    equality holds at equal plan.)"""
    base = jax.random.PRNGKey(seed)
    return {name: jax.random.fold_in(base, i)
            for i, name in enumerate(names_sorted)}


def run_sweep(problems: dict[str, SearchProblem],
              cfg: SweepConfig | None = None, **overrides) -> SweepResult:
    """Run the NSGA-II campaign over every problem in `problems`.

    Returns per-dataset `SearchResult`s (pareto genes already unpadded back
    to each problem's real 3N+1 columns) plus bucket-level dispatch/wall
    accounting. With `out_dir`, each dataset writes the standard
    `pareto.json` artifact (and RTL, per `emit_rtl`/`verify_rtl`) under
    `out_dir/<dataset>/` through the single-run pipeline.
    """
    cfg = dataclasses.replace(cfg or SweepConfig(), **overrides)
    if not problems:
        raise ValueError("run_sweep needs at least one problem")
    if (cfg.emit_rtl or cfg.verify_rtl) and not cfg.out_dir:
        raise ValueError("emit_rtl/verify_rtl require out_dir")
    mesh = None
    if cfg.mesh:
        from repro.launch.mesh import make_search_mesh

        if not cfg.vmapped:
            raise ValueError("mesh sharding requires the vmapped path "
                             "(the serial loop is the mesh-free oracle)")
        mesh = make_search_mesh(cfg.mesh, axes=("bucket", "pop"))
        if mesh is not None and cfg.pop_size % mesh.shape["pop"]:
            raise ValueError(
                f"pop_size={cfg.pop_size} not divisible by the mesh's pop "
                f"axis ({mesh.shape['pop']})")

    from repro.families import get_family

    names_sorted = sorted(problems)
    keys = _problem_keys(names_sorted, cfg.seed)
    buckets = plan_buckets(problems, granule=cfg.granule,
                           max_buckets=cfg.max_buckets)
    nsga_cfg = nsga2.NSGA2Config(pop_size=cfg.pop_size,
                                 n_generations=cfg.n_generations)

    t0 = time.time()
    results: dict[str, _engine.SearchResult] = {}
    bucket_runs: list[BucketRun] = []
    for bucket in buckets:
        t_b = time.time()
        fam = get_family(bucket.family)
        fam_objectives = fam.population_objectives
        padded = [fam.pad_problem(problems[n], bucket.dims)
                  for n in bucket.names]
        bucket_keys = jnp.stack([keys[n] for n in bucket.names])
        n_genes = fam.padded_n_genes(bucket.dims)
        seed_genes = fam.padded_exact_genes(bucket.dims)

        if cfg.vmapped:
            n_real = len(padded)
            if mesh is not None:
                # the stacked problem axis shards over the bucket mesh axis:
                # pad the stack by repeating the last problem (extra lanes
                # are pure compute waste, dropped below) so it divides
                kb = mesh.shape["bucket"]
                pad_k = (-n_real) % kb
                padded = padded + [padded[-1]] * pad_k
                if pad_k:
                    bucket_keys = jnp.concatenate(
                        [bucket_keys, jnp.tile(bucket_keys[-1:], (pad_k, 1))])
            stacked = stack_padded(padded)
            init = jax.jit(nsga2.make_batched_init(
                fam_objectives, n_genes, nsga_cfg,
                seed_genes=seed_genes))
            states = init(bucket_keys, stacked)
            if mesh is None:
                chunk = jax.jit(nsga2.make_batched_chunk(
                    fam_objectives, nsga_cfg, cfg.n_generations))
                states = chunk(states, stacked)
            else:
                # lay the stack over the (bucket, pop) mesh and advance the
                # whole bucket with the sharded generation (DESIGN.md §13) —
                # bit-identical lanes, so unpadding below is unchanged
                from jax.sharding import NamedSharding, PartitionSpec as P
                from repro.core import dist
                from repro.sharding import search as _sspec

                states = jax.tree.map(jax.device_put, states,
                                      _sspec.batched_state_sharding(mesh))
                ctx_shard = NamedSharding(mesh, P("bucket"))
                stacked = jax.tree.map(
                    lambda a: jax.device_put(a, ctx_shard), stacked)
                chunk = dist.make_sharded_batched_chunk(
                    fam_objectives, mesh, nsga_cfg,
                    cfg.n_generations)
                states = chunk(states, stacked)
            states = jax.device_get(states)
            per_problem = [
                jax.tree_util.tree_map(lambda a, i=i: a[i], states)
                for i in range(n_real)]
            n_dispatches = 2
        else:
            # serial oracle: the SAME padded problems through the un-vmapped
            # chunked scan, one at a time. Like the vmapped path, both
            # stages are jitted AND take the padded problem as an argument
            # (closed-over operands would constant-fold and round
            # differently; eager evaluation likewise) — that symmetry is
            # what the bit-exactness contract rests on.
            init_fn = jax.jit(lambda key, pp: nsga2.init_state(
                key, lambda pop: fam_objectives(pp, pop),
                n_genes, nsga_cfg, seed_genes=seed_genes))
            chunk_fn = jax.jit(lambda state, pp: nsga2.make_chunk(
                lambda pop: fam_objectives(pp, pop),
                nsga_cfg, cfg.n_generations)(state))
            per_problem = []
            n_dispatches = 0
            for pp, key in zip(padded, bucket_keys):
                state = init_fn(key, pp)
                state = chunk_fn(state, pp)
                per_problem.append(jax.device_get(state))
                n_dispatches += 2
        wall_b = time.time() - t_b
        bucket_runs.append(BucketRun(bucket, n_dispatches, wall_b))

        for name, state in zip(bucket.names, per_problem):
            problem = problems[name]
            genes = fam.unpad_genes(problem, np.asarray(state.genes),
                                    bucket.dims)
            objs = np.asarray(state.objs)
            p_objs, p_genes = nsga2.pareto_front(objs, genes)
            result = _engine.SearchResult(
                state=state,
                pareto_objs=np.asarray(p_objs),
                pareto_genes=np.asarray(p_genes),
                backend="sweep" if cfg.vmapped else "sweep-serial",
                wall_s=wall_b,
                n_evaluations=cfg.pop_size * (1 + cfg.n_generations),
                n_dispatches=n_dispatches,  # shared across the bucket
            )
            results[name] = result
            if cfg.out_dir:
                fam.write_artifact(
                    problem, result, os.path.join(cfg.out_dir, name),
                    emit_rtl=cfg.emit_rtl, verify_rtl=cfg.verify_rtl,
                    dataset=name)

    return SweepResult(results=results, bucket_runs=bucket_runs,
                       wall_s=time.time() - t0)


# ---------------------------------------------------------------------------
# campaign construction + paper scoring
# ---------------------------------------------------------------------------

def build_problems(datasets, n_trees: int = 1,
                   verbose: bool = False, *, mlp_datasets=(),
                   n_hidden: int = 16) -> dict:
    """Train the exact design per dataset: bespoke trees (or forests,
    `n_trees > 1`) for `datasets`, printed MLPs for `mlp_datasets`
    (campaign keys suffixed `_mlp` so one dataset can run in both
    families). A mixed campaign flows through the same `run_sweep`; the
    bucket planner keeps the families apart (DESIGN.md §15)."""
    from repro.core.forest import train_forest
    from repro.core.train import train_tree
    from repro.core.tree import to_parallel
    from repro.datasets import load_dataset
    from repro.search.problem import build_forest_problem, build_tree_problem

    out = {}
    for name in datasets:
        t0 = time.time()
        ds = load_dataset(name)
        if n_trees <= 1:
            tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
            problem = build_tree_problem(to_parallel(tree), ds.x_test,
                                         ds.y_test)
        else:
            forest = train_forest(ds.x_train, ds.y_train, ds.n_classes,
                                  n_trees=n_trees)
            problem = build_forest_problem(forest, ds.x_test, ds.y_test)
        out[name] = problem
        if verbose:
            print(f"  {name}: comparators={problem.n_comparators} "
                  f"leaves={problem.n_leaves} "
                  f"exact_acc={problem.exact_accuracy:.3f} "
                  f"({time.time() - t0:.1f}s)")
    for name in mlp_datasets:
        from repro.families import get_family

        t0 = time.time()
        problem = get_family("mlp").build_problem(name, n_hidden=n_hidden)
        out[f"{name}_mlp"] = problem
        if verbose:
            print(f"  {name}_mlp: hidden={problem.n_hidden} "
                  f"shift={problem.shift} "
                  f"exact_acc={problem.exact_accuracy:.3f} "
                  f"({time.time() - t0:.1f}s)")
    return out


def _netlist_ratios(pareto_path: str) -> dict | None:
    """Estimated-vs-netlist area spread from a written pareto.json."""
    if not os.path.exists(pareto_path):
        return None
    with open(pareto_path) as f:
        artifact = json.load(f)
    ratios = _engine.netlist_area_ratios(artifact["pareto"])
    if not ratios:
        return None
    return {"min": round(min(ratios), 4),
            "mean": round(sum(ratios) / len(ratios), 4),
            "max": round(max(ratios), 4),
            "n_points": len(ratios)}


def _robustness_summary(report_path: str) -> dict | None:
    """Condensed robustness metrics from a written fault_report.json.

    Loose by design (like `_netlist_ratios`): a dataset without a fault
    campaign — or with an invalid report — simply contributes no
    robustness row rather than failing the sweep report.
    """
    from repro.search import robustness

    if not os.path.exists(report_path):
        return None
    try:
        report = robustness.load_fault_report(report_path)
    except (OSError, ValueError):
        return None
    if not report["points"]:
        return None
    pt = report["points"][0]    # --fault-report runs the best point
    return {
        "point": pt["point"],
        "norm_area": round(pt["norm_area"], 4),
        "n_sites": pt["n_sites"],
        "baseline_accuracy": round(pt["baseline_accuracy"], 4),
        "single_fault_mean_accuracy":
            round(pt["single_fault"]["mean_accuracy"], 4),
        "single_fault_worst_accuracy":
            round(pt["single_fault"]["worst_accuracy"], 4),
        "mc_expected_accuracy":
            round(pt["monte_carlo"]["expected_accuracy"], 4),
        "defect_rate": report["defect_rate"],
    }


def write_sweep_report(sweep: SweepResult,
                       problems: dict[str, SearchProblem],
                       out_dir: str, *, meta: dict | None = None,
                       max_loss: float = 0.01) -> tuple[str, str]:
    """Score the campaign against the paper and write the report artifacts.

    Emits `out_dir/sweep_report.json` (machine-readable: per-dataset
    accuracy deltas vs Table I, normalized area at the loss budget vs
    Table II, estimated-vs-netlist spreads from each dataset's pareto.json,
    bucket/dispatch accounting) and `out_dir/REPORT.md` (the same as one
    human-readable table). Returns (json_path, md_path).
    """
    from repro.datasets.paper_refs import (
        PAPER_MEAN_AREA_REDUCTION_1PCT,
        PAPER_TABLE1,
        PAPER_TABLE2_NORM,
    )

    os.makedirs(out_dir, exist_ok=True)
    rows: dict[str, dict] = {}
    reductions = []
    acc_deltas = []
    for name in sorted(sweep.results):
        result = sweep.results[name]
        problem = problems[name]
        paper1 = PAPER_TABLE1.get(name)
        paper2 = PAPER_TABLE2_NORM.get(name)
        if hasattr(problem, "n_comparators"):   # tree row (schema unchanged)
            row: dict = {
                "exact_accuracy": round(problem.exact_accuracy, 4),
                "n_comparators": problem.n_comparators,
                "n_trees": problem.n_trees,
                "exact_area_mm2": round(problem.exact_area_mm2, 2),
                "n_pareto_points": int(len(result.pareto_objs)),
                "wall_s": round(result.wall_s, 2),
            }
        else:                                   # printed-MLP row
            row = {
                "family": "mlp",
                "exact_accuracy": round(problem.exact_accuracy, 4),
                "n_hidden": problem.n_hidden,
                "exact_area_mm2": round(problem.exact_area_mm2, 2),
                "n_pareto_points": int(len(result.pareto_objs)),
                "wall_s": round(result.wall_s, 2),
            }
            paper1 = paper2 = None  # paper tables are tree-family numbers
        if paper1:
            row["paper_accuracy"] = paper1[0]
            row["accuracy_delta"] = round(problem.exact_accuracy - paper1[0], 4)
            row["paper_n_comparators"] = paper1[1]
            row["paper_area_mm2"] = paper1[3]
            acc_deltas.append(abs(row["accuracy_delta"]))
        best = result.best_under_loss(max_loss)
        if best is not None:
            objs, _ = best
            norm_area = float(objs[1])
            area_mm2 = norm_area * problem.exact_area_mm2
            row["at_budget"] = {
                "max_loss": max_loss,
                "acc_loss": round(float(objs[0]), 4),
                "norm_area": round(norm_area, 4),
                "area_mm2": round(area_mm2, 2),
                "power_mw": round(area_mod.power_mw(area_mm2), 3),
            }
            if norm_area > 0:
                reductions.append(1.0 / norm_area)
            if paper2:
                row["at_budget"]["paper_norm_area"] = paper2[0]
                row["at_budget"]["norm_area_delta"] = round(
                    norm_area - paper2[0], 4)
        else:
            row["at_budget"] = None
        ratios = _netlist_ratios(os.path.join(out_dir, name, "pareto.json"))
        if ratios:
            row["netlist_vs_estimated_area"] = ratios
        robust = _robustness_summary(
            os.path.join(out_dir, name, "fault_report.json"))
        if robust:
            row["robustness"] = robust
        rows[name] = row

    payload = {
        "meta": meta or {},
        "buckets": [{
            "datasets": list(r.bucket.names),
            "family": r.bucket.family,
            "dims": r.bucket.dims_dict(),
            "n_dispatches": r.n_dispatches,
            "wall_s": round(r.wall_s, 2),
        } for r in sweep.bucket_runs],
        "n_dispatches": sweep.n_dispatches,
        "serial_baseline_dispatches": sweep.serial_baseline_dispatches(),
        "wall_s": round(sweep.wall_s, 2),
        "datasets": rows,
        "summary": {
            "n_datasets": len(rows),
            "n_at_budget": len(reductions),
            "mean_area_reduction_at_budget":
                round(float(np.mean(reductions)), 3) if reductions else None,
            "paper_mean_area_reduction_1pct": PAPER_MEAN_AREA_REDUCTION_1PCT,
            "mean_abs_accuracy_delta_vs_paper":
                round(float(np.mean(acc_deltas)), 4) if acc_deltas else None,
        },
    }
    json_path = os.path.join(out_dir, "sweep_report.json")
    tmp = json_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, json_path)

    md_path = os.path.join(out_dir, "REPORT.md")
    with open(md_path + ".tmp", "w") as f:
        f.write(_report_markdown(payload, max_loss))
    os.replace(md_path + ".tmp", md_path)
    return json_path, md_path


def _report_markdown(payload: dict, max_loss: float) -> str:
    lines = ["# Full-suite sweep report", ""]
    meta = payload.get("meta") or {}
    if meta:
        opts = " ".join(f"{k}={v}" for k, v in sorted(meta.items()))
        lines += [f"Campaign: {opts}", ""]
    lines += [
        f"Dispatches: **{payload['n_dispatches']}** over "
        f"{len(payload['buckets'])} buckets (serial per-dataset baseline: "
        f"{payload['serial_baseline_dispatches']}); "
        f"wall {payload['wall_s']}s.",
        "",
        "| bucket | family | datasets | padded dims | dispatches |",
        "|---|---|---|---|---|",
    ]
    for i, b in enumerate(payload["buckets"]):
        d = b["dims"]
        dims = "(" + ", ".join(str(v) for v in d.values()) + ")"
        lines.append(f"| {i} | {b.get('family', 'tree')} "
                     f"| {', '.join(b['datasets'])} | {dims} "
                     f"| {b['n_dispatches']} |")
    lines += [
        "",
        f"Per dataset, scored against paper Tables I/II "
        f"(budget: {max_loss:.0%} accuracy loss):",
        "",
        "| dataset | acc (paper) | Δacc | comparators (paper) "
        "| norm area @budget (paper) | netlist/LUT mean |",
        "|---|---|---|---|---|---|",
    ]
    for name, row in payload["datasets"].items():
        pacc = row.get("paper_accuracy")
        acc = (f"{row['exact_accuracy']:.3f} ({pacc:.3f})"
               if pacc is not None else f"{row['exact_accuracy']:.3f} (—)")
        dacc = (f"{row['accuracy_delta']:+.3f}"
                if "accuracy_delta" in row else "—")
        if "n_comparators" in row:
            ncmp = (f"{row['n_comparators']} ({row['paper_n_comparators']})"
                    if "paper_n_comparators" in row
                    else f"{row['n_comparators']} (—)")
        else:
            ncmp = f"mlp h={row['n_hidden']}"
        at = row.get("at_budget")
        if at:
            pna = at.get("paper_norm_area")
            na = (f"{at['norm_area']:.3f} ({pna:.3f})"
                  if pna is not None else f"{at['norm_area']:.3f} (—)")
        else:
            na = "none under budget"
        ratios = row.get("netlist_vs_estimated_area")
        ratio = f"{ratios['mean']:.2f}" if ratios else "—"
        lines.append(f"| {name} | {acc} | {dacc} | {ncmp} | {na} | {ratio} |")
    s = payload["summary"]
    lines += [
        "",
        f"Mean area reduction at budget: "
        f"**{s['mean_area_reduction_at_budget']}x** over "
        f"{s['n_at_budget']}/{s['n_datasets']} datasets "
        f"(paper: {s['paper_mean_area_reduction_1pct']}x at 1%). "
        f"Mean |Δaccuracy| vs Table I: "
        f"{s['mean_abs_accuracy_delta_vs_paper']}.",
        "",
    ]
    robust = {name: row["robustness"]
              for name, row in payload["datasets"].items()
              if row.get("robustness")}
    if robust:
        rate = next(iter(robust.values()))["defect_rate"]
        lines += [
            "## Robustness vs area (stuck-at campaign, DESIGN.md §17)",
            "",
            f"Best-under-budget point per dataset: exhaustive single "
            f"stuck-at over every fault site + Monte-Carlo expected "
            f"accuracy at a {rate:.0%} iid defect rate.",
            "",
            "| dataset | point | norm area | sites | baseline acc "
            "| 1-fault mean | 1-fault worst | MC expected |",
            "|---|---|---|---|---|---|---|---|",
        ]
        for name, r in robust.items():
            lines.append(
                f"| {name} | {r['point']} | {r['norm_area']:.3f} "
                f"| {r['n_sites']} | {r['baseline_accuracy']:.3f} "
                f"| {r['single_fault_mean_accuracy']:.3f} "
                f"| {r['single_fault_worst_accuracy']:.3f} "
                f"| {r['mc_expected_accuracy']:.3f} |")
        lines.append("")
    return "\n".join(lines)
