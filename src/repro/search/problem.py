"""`SearchProblem`: one evaluation context for tree *and* forest GA search.

The paper's design-space search is always the same shape — NSGA-II over
per-comparator (precision, margin) genes, each chromosome scored as
(accuracy loss, normalized area) against an exact bespoke reference — but the
seed repo grew three hand-rolled copies of it (single tree in `core.approx`,
forest in `core.forest`, islands in `core.dist`). This module collapses the
*data* side of all three into one immutable problem object (DESIGN.md §7):

  - the comparator axis is the concatenation of every tree's comparators
    (a single tree is the K=1 case), so one chromosome of 3*N_total + 1
    genes — per-comparator (precision, margin, truncation) plus the
    forest-wide vote-adder gene (DESIGN.md §16) — covers the whole ensemble
    exactly like `core.forest`'s joint search;
  - the leaf axis concatenates every tree's leaves and `path` is the
    block-diagonal "super-tree" path matrix, so leaf decode + the class-vote
    matmul evaluate every tree in one fused tensor program — the same
    operands the Pallas kernel consumes (`repro.kernels.tree_infer`);
  - area bookkeeping (LUT, offsets, overheads, exact-design reference) is
    computed once here instead of per-pipeline.

Fitness *backends* over this object live in `repro.search.backends`; the
driver loop in `repro.search.engine`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import area as area_mod
from repro.core import quant
from repro.core.tree import ParallelTree, concatenate_ptrees
from repro.datasets.synthetic import quantize_u8


@dataclasses.dataclass
class SearchProblem:
    """Immutable evaluation context for one (tree-ensemble, dataset) pair.

    All comparator/leaf arrays are concatenated across the K trees of the
    ensemble (K = 1 for a single tree); `path` is block-diagonal.
    """

    feature: jnp.ndarray      # (N,) int32   concatenated comparator features
    threshold: jnp.ndarray    # (N,) float32 trained float thresholds
    path: jnp.ndarray         # (L, N) int8  block-diagonal super-tree paths
    path_len: jnp.ndarray     # (L,) int32
    n_neg: jnp.ndarray        # (L,) int32
    leaf_class: jnp.ndarray   # (L,) int32
    leaf_tree: jnp.ndarray    # (L,) int32   owning tree per leaf
    x8: jnp.ndarray           # (B, F) int32 master codes (test set)
    x_sel: jnp.ndarray        # (B, N) int32 hoisted x8[:, feature] — the
                              #   chromosome-invariant feature gather,
                              #   computed once per problem (DESIGN.md §12)
    y: jnp.ndarray            # (B,) int32
    area_lut: jnp.ndarray     # flat LUT (mm^2)
    lut_offsets: jnp.ndarray  # (MAX_BITS+1,) int32
    overhead_mm2: float
    exact_area_mm2: float
    exact_accuracy: float
    n_classes: int
    n_features: int
    n_trees: int
    tree_comparators: tuple   # per-tree comparator counts (static)
    tree_leaves: tuple        # per-tree leaf counts (static)
    vote_mm2_exact: float = 0.0   # vote-stage area per adder mode — priced
    vote_mm2_approx: float = 0.0  # from the netlist harness (DESIGN.md §16)

    @property
    def n_comparators(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_leaves(self) -> int:
        return int(self.leaf_class.shape[0])

    @property
    def n_genes(self) -> int:
        """Cross-layer chromosome length (DESIGN.md §16): three genes per
        comparator (precision, margin, truncation) + the vote-adder gene."""
        return 3 * self.n_comparators + 1

    def exact_genes(self) -> np.ndarray:
        """Chromosome of the exact (8-bit, zero-margin, un-truncated,
        exact-vote) reference design."""
        return quant.exact_tree_genes(self.n_comparators)


jax.tree_util.register_pytree_node(
    SearchProblem,
    lambda p: (
        (p.feature, p.threshold, p.path, p.path_len, p.n_neg, p.leaf_class,
         p.leaf_tree, p.x8, p.x_sel, p.y, p.area_lut, p.lut_offsets),
        (p.overhead_mm2, p.exact_area_mm2, p.exact_accuracy, p.n_classes,
         p.n_features, p.n_trees, p.tree_comparators, p.tree_leaves,
         p.vote_mm2_exact, p.vote_mm2_approx),
    ),
    lambda aux, children: SearchProblem(*children, *aux),
)


# ---------------------------------------------------------------------------
# reference (pure-jnp) evaluation primitives shared by backends
# ---------------------------------------------------------------------------

def decode_chromosome(problem: SearchProblem, genes):
    """genes (..., 3N+1) -> (bits, t_sub, vote_cap): the EFFECTIVE design.

    Decodes the cross-layer chromosome (DESIGN.md §16) and folds LSB
    truncation into the returned pair — `bits` is the effective comparator
    width p - k and `t_sub` the substituted threshold shifted down by k —
    because a k-truncated comparator IS the exact comparator at that
    width/threshold. `vote_cap` is the f32 saturation the vote counts are
    clipped to before argmax: 1.0 under the approximate OR-tree adder,
    +inf (an exact f32 no-op) under the exact popcount adder.
    """
    bits, margin, trunc, vote = quant.decode_tree_genes(genes)
    t_int = quant.threshold_to_int(problem.threshold, bits)
    t_sub = quant.substitute(t_int, margin, bits)
    vote_cap = jnp.where(vote > 0, jnp.float32(1.0), jnp.float32(jnp.inf))
    return bits - trunc, jnp.right_shift(t_sub, trunc), vote_cap


def vote_area_mm2(problem: SearchProblem, vote_cap):
    """Vote-stage area term selected by the decoded cap (0 when K = 1)."""
    return jnp.where(jnp.isfinite(vote_cap),
                     jnp.float32(problem.vote_mm2_approx),
                     jnp.float32(problem.vote_mm2_exact))


def predict_votes(problem: SearchProblem, bits, t_sub, vote_cap=None):
    """(B,) voted class per sample — the block-diagonal super-tree dataflow.

    Exactly one leaf per tree satisfies its path, so `sat @ CLS1H` counts one
    vote per tree per class; for K=1 the votes are the predicted class's
    one-hot and this reduces bit-exactly to single-tree leaf decode.

    The feature gather is hoisted: `problem.x_sel` is the chromosome-
    invariant `x8[:, feature]`, computed once at problem build, so the
    per-chromosome work starts at the precision shift + broadcast compare
    (DESIGN.md §12).
    """
    x_p = quant.inputs_at_precision(problem.x_sel, bits)
    d = (x_p > t_sub[None, :]).astype(jnp.float32)
    score = d @ problem.path.T.astype(jnp.float32)           # (B, L)
    target = (problem.path_len - problem.n_neg).astype(jnp.float32)
    sat = (score == target[None, :]).astype(jnp.float32)
    cls1h = jax.nn.one_hot(problem.leaf_class, problem.n_classes)
    votes = sat @ cls1h                                      # (B, C)
    if vote_cap is not None:
        # saturating (approximate) vote adder; +inf cap = exact no-op
        votes = jnp.minimum(votes, vote_cap)
    return jnp.argmax(votes, axis=1)


def chromosome_accuracy(problem: SearchProblem, genes):
    bits, t_sub, vote_cap = decode_chromosome(problem, genes)
    pred = predict_votes(problem, bits, t_sub, vote_cap)
    return jnp.mean((pred == problem.y).astype(jnp.float32))


def chromosome_area_mm2(problem: SearchProblem, genes):
    """Additive LUT area (the paper's GA estimator) + per-node overheads +
    the vote-adder cell of the decoded mode (DESIGN.md §16)."""
    bits, t_sub, vote_cap = decode_chromosome(problem, genes)
    idx = problem.lut_offsets[bits] + t_sub
    return (problem.area_lut[idx].sum() + problem.overhead_mm2
            + vote_area_mm2(problem, vote_cap))


def objectives(problem: SearchProblem, genes):
    """(accuracy_loss vs exact, normalized area) — both minimized.

    ONE shared gene decode feeds both objectives (DESIGN.md §12): the
    accuracy term consumes the effective (bits, t_sub, vote_cap) for the
    comparator/vote eval, the area term reuses the same triple as the LUT
    index + vote-adder cell — historically each objective decoded the
    chromosome independently, doubling the decode work per eval.
    """
    bits, t_sub, vote_cap = decode_chromosome(problem, genes)
    pred = predict_votes(problem, bits, t_sub, vote_cap)
    acc = jnp.mean((pred == problem.y).astype(jnp.float32))
    idx = problem.lut_offsets[bits] + t_sub
    area = (problem.area_lut[idx].sum() + problem.overhead_mm2
            + vote_area_mm2(problem, vote_cap))
    return jnp.stack([problem.exact_accuracy - acc,
                      area / problem.exact_area_mm2])


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def build_problem(ptrees, x_test: np.ndarray, y_test: np.ndarray,
                  n_classes: int | None = None) -> SearchProblem:
    """Build a SearchProblem from one or more `ParallelTree`s.

    `ptrees` may be a single tree or a list (forest, joint chromosome).
    """
    if isinstance(ptrees, ParallelTree):
        ptrees = [ptrees]
    if n_classes is None:
        n_classes = max(pt.n_classes for pt in ptrees)
    n_features = int(x_test.shape[1])

    arrays = concatenate_ptrees(ptrees)
    feature, threshold, path = (arrays["feature"], arrays["threshold"],
                                arrays["path"])
    path_len, n_neg = arrays["path_len"], arrays["n_neg"]
    leaf_class, leaf_tree = arrays["leaf_class"], arrays["leaf_tree"]
    n_total = feature.shape[0]
    l_total = leaf_class.shape[0]

    lut, offsets = area_mod.build_area_lut()
    x8 = quantize_u8(x_test).astype(np.int32)
    overhead = area_mod.tree_overhead_mm2(n_total, l_total)
    # vote-adder cells, priced from the isolated netlist harness (§16);
    # both zero for K = 1 (no vote stage exists — the gene is inert)
    vote_exact = area_mod.vote_adder_area_mm2(len(ptrees), int(n_classes),
                                              approx=False)
    vote_approx = area_mod.vote_adder_area_mm2(len(ptrees), int(n_classes),
                                               approx=True)

    # exact design: 8-bit, zero margin, exact vote adder (float64 LUT sum,
    # like core.approx)
    t8 = np.clip(np.floor(threshold.astype(np.float64) * 256.0), 0, 255)
    t8 = t8.astype(np.int64)
    exact_bits = np.full(n_total, quant.MAX_BITS, dtype=np.int64)
    exact_area = float(lut[offsets[exact_bits] + t8].sum() + overhead
                       + vote_exact)

    problem = SearchProblem(
        feature=jnp.asarray(feature),
        threshold=jnp.asarray(threshold),
        path=jnp.asarray(path),
        path_len=jnp.asarray(path_len),
        n_neg=jnp.asarray(n_neg),
        leaf_class=jnp.asarray(leaf_class),
        leaf_tree=jnp.asarray(leaf_tree),
        x8=jnp.asarray(x8),
        x_sel=jnp.asarray(x8[:, feature]),
        y=jnp.asarray(y_test.astype(np.int32)),
        area_lut=jnp.asarray(lut),
        lut_offsets=jnp.asarray(offsets),
        overhead_mm2=float(overhead),
        exact_area_mm2=exact_area,
        exact_accuracy=0.0,  # filled below
        n_classes=int(n_classes),
        n_features=n_features,
        n_trees=len(ptrees),
        tree_comparators=tuple(pt.n_comparators for pt in ptrees),
        tree_leaves=tuple(pt.n_leaves for pt in ptrees),
        vote_mm2_exact=float(vote_exact),
        vote_mm2_approx=float(vote_approx),
    )
    exact_acc = float(chromosome_accuracy(
        problem, jnp.asarray(quant.exact_tree_genes(n_total))))
    return dataclasses.replace(problem, exact_accuracy=exact_acc)


def problem_ptrees(problem: SearchProblem) -> list:
    """Recover the per-tree `ParallelTree`s from the concatenated layout.

    The block-diagonal super-tree is sliced back apart using the static
    per-tree comparator/leaf counts, so the hardware pipeline (netlist
    build, RTL emission, DESIGN.md §10) needs only the `SearchProblem` —
    the original trees don't have to be threaded through the engine.
    """
    feature = np.asarray(problem.feature)
    threshold = np.asarray(problem.threshold)
    path = np.asarray(problem.path)
    path_len = np.asarray(problem.path_len)
    n_neg = np.asarray(problem.n_neg)
    leaf_class = np.asarray(problem.leaf_class)
    ptrees, n_off, l_off = [], 0, 0
    for n_k, l_k in zip(problem.tree_comparators, problem.tree_leaves):
        block = path[l_off:l_off + l_k, n_off:n_off + n_k]
        if n_k == 0:  # single-leaf tree: ParallelTree keeps one dummy column
            block = np.zeros((l_k, 1), np.int8)
        ptrees.append(ParallelTree(
            feature=feature[n_off:n_off + n_k],
            threshold=threshold[n_off:n_off + n_k],
            path=np.ascontiguousarray(block),
            path_len=path_len[l_off:l_off + l_k],
            n_neg=n_neg[l_off:l_off + l_k],
            leaf_class=leaf_class[l_off:l_off + l_k],
            n_classes=problem.n_classes,
        ))
        n_off += n_k
        l_off += l_k
    return ptrees


def build_tree_problem(ptree: ParallelTree, x_test, y_test) -> SearchProblem:
    return build_problem(ptree, x_test, y_test)


def build_forest_problem(forest, x_test, y_test) -> SearchProblem:
    """`forest` is a `repro.core.forest.Forest`."""
    return build_problem(list(forest.ptrees), x_test, y_test,
                         n_classes=forest.n_classes)
