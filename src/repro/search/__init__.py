"""Unified design-space search engine (DESIGN.md §7).

One abstraction for every NSGA-II dual-approximation search the repo runs:

  `SearchProblem`   — comparator arrays + block-diagonal super-tree path
                      matrices + dataset + area LUT + exact reference,
                      covering a single `ParallelTree` and a `Forest` alike;
  fitness backends  — `reference` (pure jnp), `kernel` (fused Pallas
                      multi-tree inference), `islands` (per-device GA with
                      ring migration);
  `run_search`      — the one driver: checkpointable state, pareto-front
                      artifacts, backend selection;
  `run_sweep`       — the batched multi-dataset campaign (DESIGN.md §11):
                      problems padded to bucket boundaries and advanced with
                      one vmapped dispatch per bucket per stage, scored
                      against the paper's Tables I/II.

CLI: ``python -m repro.search --dataset seeds --backend kernel --trees 4``
or ``python -m repro.search sweep --datasets all --report``.
"""
from repro.search.problem import (
    SearchProblem,
    build_problem,
    build_tree_problem,
    build_forest_problem,
    chromosome_accuracy,
    chromosome_area_mm2,
    decode_chromosome,
    objectives,
    predict_votes,
    problem_ptrees,
)
from repro.search.backends import (
    BACKENDS,
    make_fitness,
    make_kernel_fitness,
    make_reference_fitness,
)
from repro.search.engine import (
    SearchConfig,
    SearchResult,
    netlist_area_ratios,
    run_search,
    write_pareto_artifact,
)
from repro.search.sweep import (
    SweepConfig,
    SweepResult,
    build_problems,
    pad_problem,
    plan_buckets,
    round_up_pow2,
    run_sweep,
    write_sweep_report,
)
from repro.search.artifact import (
    ParetoArtifact,
    load_pareto_artifact,
)
from repro.search.robustness import (
    load_fault_report,
    run_campaign,
    validate_fault_report,
    write_fault_report,
)

__all__ = [
    "SearchProblem",
    "build_problem",
    "build_tree_problem",
    "build_forest_problem",
    "chromosome_accuracy",
    "chromosome_area_mm2",
    "decode_chromosome",
    "objectives",
    "predict_votes",
    "problem_ptrees",
    "BACKENDS",
    "make_fitness",
    "make_kernel_fitness",
    "make_reference_fitness",
    "SearchConfig",
    "SearchResult",
    "netlist_area_ratios",
    "run_search",
    "write_pareto_artifact",
    "SweepConfig",
    "SweepResult",
    "build_problems",
    "pad_problem",
    "plan_buckets",
    "round_up_pow2",
    "run_sweep",
    "write_sweep_report",
    "ParetoArtifact",
    "load_pareto_artifact",
]
