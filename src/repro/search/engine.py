"""`run_search`: the one NSGA-II driver behind tree, forest and island search.

Collapses the three hand-rolled GA loops (core.approx quickstart path,
core.forest fitness, core.dist islands) into a single entry point
(DESIGN.md §7):

    problem = search.build_tree_problem(ptree, x_test, y_test)
    result  = search.run_search(problem, SearchConfig(backend="kernel"))

Features over the old loops:
  - backend selection: `reference` (pure jnp), `kernel` (fused Pallas,
    one launch per generation for the whole population x test-set x forest
    product), `islands` (per-device NSGA-II + ring migration via core.dist);
  - checkpointable state: `checkpoint_every` saves the full NSGA2State
    through `repro.runtime.checkpoint` (atomic, retained-K) and
    `resume=True` continues from the latest checkpoint;
  - pareto-front artifacts: `out_dir` receives pareto.json (objectives,
    genes, decoded per-comparator designs) for downstream RTL emission.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nsga2, quant
from repro.search import backends as _backends
from repro.search.problem import SearchProblem


@dataclasses.dataclass
class SearchConfig:
    backend: str = "reference"      # reference | kernel | islands
    pop_size: int = 64
    n_generations: int = 40
    seed: int = 0
    seed_exact: bool = True         # inject the exact design into the init pop
    # kernel backend
    block_b: int = 256
    block_l: int | None = None
    interpret: bool | None = None   # None = auto (interpret off TPU)
    # islands backend (generations round UP to whole migration rounds;
    # checkpoint_every/resume are not supported and raise)
    migrate_every: int = 5
    n_migrate: int = 4
    # artifacts / checkpointing
    out_dir: str | None = None
    checkpoint_every: int = 0       # generations between saves; 0 = off
    resume: bool = False


@dataclasses.dataclass
class SearchResult:
    state: nsga2.NSGA2State
    pareto_objs: np.ndarray    # (K, 2) accuracy-loss / normalized-area
    pareto_genes: np.ndarray   # (K, 2N)
    backend: str
    wall_s: float
    n_evaluations: int

    def best_under_loss(self, max_loss: float = 0.01):
        """Smallest-area pareto point within an accuracy-loss budget."""
        ok = self.pareto_objs[:, 0] <= max_loss + 1e-9
        if not ok.any():
            return None
        idx = np.flatnonzero(ok)
        best = idx[np.argmin(self.pareto_objs[idx, 1])]
        return self.pareto_objs[best], self.pareto_genes[best]


def _ckpt_dir(cfg: SearchConfig) -> str | None:
    return os.path.join(cfg.out_dir, "ckpt") if cfg.out_dir else None


def _seed_genes(problem: SearchProblem, cfg: SearchConfig):
    return problem.exact_genes() if cfg.seed_exact else None


def _restore_template(problem: SearchProblem, cfg: SearchConfig):
    """NSGA2State skeleton for checkpoint.restore — shapes/dtypes only, no
    fitness evaluation (init_state would run a full population eval just to
    be overwritten by the restored arrays)."""
    p = cfg.pop_size
    return nsga2.NSGA2State(
        genes=jnp.zeros((p, problem.n_genes), jnp.float32),
        objs=jnp.zeros((p, 2), jnp.float32),
        rank=jnp.zeros((p,), jnp.int32),
        crowd=jnp.zeros((p,), jnp.float32),
        key=jax.random.PRNGKey(0),
        generation=jnp.int32(0),
    )


def _run_single(problem: SearchProblem, cfg: SearchConfig, fitness):
    """reference/kernel driver with optional checkpoint/resume.

    Returns (state, n_evaluations actually run in THIS call)."""
    from repro.runtime import checkpoint

    nsga_cfg = nsga2.NSGA2Config(pop_size=cfg.pop_size,
                                 n_generations=cfg.n_generations)
    key = jax.random.PRNGKey(cfg.seed)
    state = None
    start_gen = 0
    n_evals = 0
    ckpt_dir = _ckpt_dir(cfg)
    if cfg.resume and ckpt_dir:
        step = checkpoint.latest_step(ckpt_dir)
        if step is not None:
            state, start_gen = checkpoint.restore(
                ckpt_dir, step, _restore_template(problem, cfg))

    if state is None:
        state = nsga2.init_state(key, fitness, problem.n_genes, nsga_cfg,
                                 seed_genes=_seed_genes(problem, cfg))
        n_evals += cfg.pop_size

    step_fn = jax.jit(nsga2.make_step(fitness, nsga_cfg))
    last_saved = start_gen if start_gen else -1
    cur_gen = start_gen
    for gen in range(start_gen, cfg.n_generations):
        state = step_fn(state)
        cur_gen = gen + 1
        n_evals += cfg.pop_size
        if (ckpt_dir and cfg.checkpoint_every
                and cur_gen % cfg.checkpoint_every == 0):
            checkpoint.save(ckpt_dir, cur_gen, state)
            last_saved = cur_gen
    # final save, but never mislabel: only when the state really is at
    # cur_gen and that generation wasn't already saved
    if ckpt_dir and cfg.checkpoint_every and last_saved != cur_gen:
        checkpoint.save(ckpt_dir, cur_gen, state)
    return state, n_evals


def _run_islands(problem: SearchProblem, cfg: SearchConfig):
    """Island driver: one NSGA-II island per device, ring migration.

    Generations are rounded UP to whole migration rounds (migrate_every
    each), so the islands backend may run slightly more generations than
    configured; `n_evaluations` reports what actually ran. Checkpointing is
    not wired into the island loop yet — rejected explicitly below rather
    than silently ignored."""
    from jax.sharding import Mesh
    from repro.core import dist

    if cfg.checkpoint_every or cfg.resume:
        raise ValueError(
            "backend='islands' does not support checkpoint_every/resume yet; "
            "drive repro.core.dist directly (see examples/distributed_ga.py) "
            "or use the reference/kernel backends for checkpointed runs")

    fitness = _backends.make_reference_fitness(problem)
    devices = np.array(jax.devices())
    n_islands = len(devices)
    local_pop = max(8, cfg.pop_size // max(n_islands, 1))
    island_cfg = dist.IslandConfig(
        local_pop=local_pop,
        migrate_every=cfg.migrate_every,
        n_migrate=min(cfg.n_migrate, local_pop // 2),
        nsga=nsga2.NSGA2Config(pop_size=local_pop,
                               n_generations=cfg.n_generations),
    )
    n_rounds = max(1, -(-cfg.n_generations // cfg.migrate_every))
    mesh = Mesh(devices, ("data",))
    state = dist.run_islands(jax.random.PRNGKey(cfg.seed), fitness,
                             problem.n_genes, mesh, island_cfg, n_rounds,
                             seed_genes=_seed_genes(problem, cfg))
    n_evals = n_islands * local_pop * (n_rounds * cfg.migrate_every + 1)
    return state, n_evals


def run_search(problem: SearchProblem, cfg: SearchConfig | None = None,
               **overrides) -> SearchResult:
    """One entry point for every search scenario.

    `overrides` are applied on top of `cfg` (or a default SearchConfig), so
    `run_search(problem, backend="kernel", pop_size=128)` works without
    building a config first.
    """
    cfg = dataclasses.replace(cfg or SearchConfig(), **overrides)
    if cfg.backend not in _backends.BACKENDS:
        raise ValueError(
            f"unknown backend {cfg.backend!r}; options: {_backends.BACKENDS}")

    t0 = time.time()
    if cfg.backend == "islands":
        state, n_evals = _run_islands(problem, cfg)
    else:
        kw = {}
        if cfg.backend == "kernel":
            kw = dict(block_b=cfg.block_b, block_l=cfg.block_l,
                      interpret=cfg.interpret)
        fitness = _backends.make_fitness(problem, cfg.backend, **kw)
        state, n_evals = _run_single(problem, cfg, fitness)
    wall_s = time.time() - t0

    objs, genes = nsga2.pareto_front(jax.device_get(state.objs),
                                     jax.device_get(state.genes))
    result = SearchResult(
        state=state,
        pareto_objs=np.asarray(objs),
        pareto_genes=np.asarray(genes),
        backend=cfg.backend,
        wall_s=wall_s,
        n_evaluations=n_evals,
    )
    if cfg.out_dir:
        write_pareto_artifact(problem, result, cfg.out_dir)
    return result


def write_pareto_artifact(problem: SearchProblem, result: SearchResult,
                          out_dir: str) -> str:
    """pareto.json: objectives + genes + decoded per-comparator designs."""
    os.makedirs(out_dir, exist_ok=True)
    points = []
    for o, g in zip(result.pareto_objs, result.pareto_genes):
        bits, margin = quant.decode_genes(jnp.asarray(g))
        points.append({
            "acc_loss": float(o[0]),
            "norm_area": float(o[1]),
            "area_mm2": float(o[1] * problem.exact_area_mm2),
            "bits": np.asarray(bits).tolist(),
            "margin": np.asarray(margin).tolist(),
            "genes": np.asarray(g, np.float64).round(6).tolist(),
        })
    payload = {
        "backend": result.backend,
        "wall_s": round(result.wall_s, 3),
        "n_evaluations": result.n_evaluations,
        "n_trees": problem.n_trees,
        "n_comparators": problem.n_comparators,
        "exact_accuracy": problem.exact_accuracy,
        "exact_area_mm2": problem.exact_area_mm2,
        "pareto": points,
    }
    path = os.path.join(out_dir, "pareto.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)
    return path
