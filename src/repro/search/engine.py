"""`run_search`: the one NSGA-II driver behind tree, forest and island search.

Collapses the three hand-rolled GA loops (core.approx quickstart path,
core.forest fitness, core.dist islands) into a single entry point
(DESIGN.md §7):

    problem = search.build_tree_problem(ptree, x_test, y_test)
    result  = search.run_search(problem, SearchConfig(backend="kernel"))

Features over the old loops:
  - backend selection: `reference` (pure jnp), `kernel` (fused Pallas,
    one launch per generation for the whole population x test-set x forest
    product), `islands` (per-device NSGA-II + ring migration via core.dist);
  - device-resident generation loop (DESIGN.md §9): generations run as
    lax.scan chunks of `checkpoint_every` (or the whole run when
    checkpointing is off), so a checkpoint interval costs exactly one host
    dispatch and one device->host transfer — `SearchResult.n_dispatches`
    reports the count;
  - checkpointable state: `checkpoint_every` saves the full NSGA2State
    through `repro.runtime.checkpoint` (atomic, retained-K) and
    `resume=True` continues from the latest checkpoint — for the islands
    backend too, whose gathered state round-trips through the same path;
  - pareto-front artifacts: `out_dir` receives pareto.json (objectives,
    genes, decoded per-comparator designs) for downstream RTL emission.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nsga2, quant
from repro.search import backends as _backends
from repro.search.problem import SearchProblem


@dataclasses.dataclass
class SearchConfig:
    backend: str = "reference"      # reference | kernel | islands
    pop_size: int = 64
    n_generations: int = 40
    seed: int = 0
    seed_exact: bool = True         # inject the exact design into the init pop
    # mesh sharding (DESIGN.md §13): a `launch.mesh.make_search_mesh` spec
    # (None = single-device oracle; "auto"/"4" shard the population axis;
    # islands interpret it as the ring size). Orthogonal to `backend`: the
    # reference and kernel fitness paths both run per-shard unmodified, and
    # checkpoints stay mesh-agnostic ("single" family) so a run can resume
    # onto a different mesh — or none — bit-exactly.
    mesh: str | None = None
    # kernel backend
    block_p: int = 8                # population-axis tile (DESIGN.md §12)
    block_b: int = 256
    block_l: int | None = None
    interpret: bool | None = None   # None = auto (interpret off TPU)
    # islands backend (generations round UP to whole migration rounds;
    # checkpoints land on round boundaries)
    migrate_every: int = 5
    n_migrate: int = 4
    # artifacts / checkpointing
    dataset: str | None = None      # dataset label recorded in pareto.json so
                                    # `python -m repro.search serve` can find
                                    # the matching test split by itself
    out_dir: str | None = None
    checkpoint_every: int = 0       # generations between saves; 0 = off
    resume: bool = False
    # hardware loop (DESIGN.md §10) — both need out_dir
    emit_rtl: bool = False          # write per-pareto-point Verilog (OUT/rtl/)
    verify_rtl: bool = False        # netlist-simulate every pareto point and
                                    # assert bit-exactness vs predict_votes
                                    # and the kernel backend


@dataclasses.dataclass
class SearchResult:
    state: nsga2.NSGA2State
    pareto_objs: np.ndarray    # (K, 2) accuracy-loss / normalized-area
    pareto_genes: np.ndarray   # (K, 3N+1) — DESIGN.md §16 gene layout
    backend: str
    wall_s: float
    n_evaluations: int
    n_dispatches: int = 0      # generation-loop device dispatches this call

    def best_under_loss(self, max_loss: float = 0.01):
        """Smallest-area pareto point within an accuracy-loss budget."""
        ok = self.pareto_objs[:, 0] <= max_loss + 1e-9
        if not ok.any():
            return None
        idx = np.flatnonzero(ok)
        best = idx[np.argmin(self.pareto_objs[idx, 1])]
        return self.pareto_objs[best], self.pareto_genes[best]


def _ckpt_dir(cfg: SearchConfig) -> str | None:
    return os.path.join(cfg.out_dir, "ckpt") if cfg.out_dir else None


def _seed_genes(problem: SearchProblem, cfg: SearchConfig):
    return problem.exact_genes() if cfg.seed_exact else None


def _chunk_schedule(start: int, stop: int, every: int) -> list[int]:
    """Chunk lengths covering [start, stop) with boundaries at multiples of
    `every` (every=0 -> one chunk for the whole remaining run). A resume from
    an off-boundary final save realigns at the next multiple, so checkpoints
    always land on the same cadence regardless of interruptions."""
    if every < 0:
        raise ValueError(f"checkpoint_every must be >= 0, got {every}")
    if start >= stop:
        return []
    if not every:
        return [stop - start]
    out = []
    g = start
    while g < stop:
        nxt = min(stop, (g // every + 1) * every)
        out.append(nxt - g)
        g = nxt
    return out


def _drive_chunks(state, start: int, stop: int, every: int, make_chunk_fn,
                  save_fn=None):
    """The chunked-scan driver shared by the single and islands families.

    Runs positions [start, stop) as lax.scan chunks with boundaries at
    multiples of `every`, compiling one chunk program per distinct length
    (at most three: realignment after an off-boundary resume, the
    steady-state `every`-long chunk, and a shorter tail). `save_fn`
    is called at every boundary and — unless that position was just saved —
    once at the end, so a partial run always leaves its final state on disk
    without ever mislabeling a step. Returns (state, position, n_chunks)."""
    chunk_fns = {}
    cur = start
    last_saved = start if start else -1
    n_chunks = 0
    for length in _chunk_schedule(start, stop, every):
        fn = chunk_fns.get(length)
        if fn is None:
            fn = chunk_fns[length] = make_chunk_fn(length)
        state = fn(state)
        cur += length
        n_chunks += 1
        if save_fn and every and cur % every == 0:
            save_fn(cur, state)
            last_saved = cur
    if save_fn and last_saved != cur:
        save_fn(cur, state)
    return state, cur, n_chunks


def _validate_resume_meta(ckpt_dir: str, step: int, family: str,
                          cfg: SearchConfig) -> dict:
    """Refuse to restore a state whose layout can't match this run.

    Returns the manifest's meta dict ({} for pre-meta checkpoints, which
    fall through to checkpoint.restore's shape asserts)."""
    from repro.runtime import checkpoint

    meta = checkpoint.read_manifest(ckpt_dir, step).get("meta", {})
    if not meta:
        return meta
    saved = meta.get("family")
    if saved != family:
        raise ValueError(
            f"checkpoint at {ckpt_dir} step {step} was written by the "
            f"{saved!r} driver; cannot resume it with backend={cfg.backend!r} "
            f"({family!r} state layout)")
    if meta.get("pop_size", cfg.pop_size) != cfg.pop_size:
        raise ValueError(
            f"checkpoint at {ckpt_dir} step {step} was written with "
            f"pop_size={meta['pop_size']}; cannot resume with "
            f"pop_size={cfg.pop_size}")
    return meta


def _restore_template(problem: SearchProblem, cfg: SearchConfig):
    """NSGA2State skeleton for checkpoint.restore — shapes/dtypes only, no
    fitness evaluation (init_state would run a full population eval just to
    be overwritten by the restored arrays)."""
    p = cfg.pop_size
    return nsga2.NSGA2State(
        genes=jnp.zeros((p, problem.n_genes), jnp.float32),
        objs=jnp.zeros((p, 2), jnp.float32),
        rank=jnp.zeros((p,), jnp.int32),
        crowd=jnp.zeros((p,), jnp.float32),
        key=jax.random.PRNGKey(0),
        generation=jnp.int32(0),
    )


def _run_single(problem: SearchProblem, cfg: SearchConfig, fitness,
                mesh=None):
    """reference/kernel driver: chunked-scan generations + checkpoint/resume.

    Returns (state, n_evaluations, n_dispatches) for THIS call. Generations
    execute as `nsga2.make_chunk` programs of `checkpoint_every` length
    (falling back to the full run), so the host dispatches once per
    checkpoint interval — bit-exact vs the historical per-generation loop.

    With a mesh the SAME schedule runs through `dist.make_sharded_chunk`
    (population axis sharded, hierarchical domination, DESIGN.md §13) —
    bit-identical arrays, so the checkpoint family stays "single" and a run
    may freely resume onto a different mesh or none (elastic restore)."""
    from repro.runtime import checkpoint

    nsga_cfg = nsga2.NSGA2Config(pop_size=cfg.pop_size,
                                 n_generations=cfg.n_generations)
    if mesh is not None:
        from repro.core import dist
        n_shards = mesh.shape["pop"]
        if cfg.pop_size % n_shards:
            raise ValueError(
                f"pop_size={cfg.pop_size} not divisible by the mesh's "
                f"pop axis ({n_shards})")
    key = jax.random.PRNGKey(cfg.seed)
    state = None
    start_gen = 0
    n_evals = 0
    n_dispatches = 0
    ckpt_dir = _ckpt_dir(cfg)
    meta = {"family": "single", "backend": cfg.backend,
            "pop_size": cfg.pop_size}
    if cfg.resume and ckpt_dir:
        step = checkpoint.latest_step(ckpt_dir)
        if step is not None:
            _validate_resume_meta(ckpt_dir, step, "single", cfg)
            state, start_gen = checkpoint.restore(
                ckpt_dir, step, _restore_template(problem, cfg),
                shardings=(dist.sharded_state_sharding(mesh)
                           if mesh is not None else None))

    if state is None:
        if mesh is not None:
            state = dist.init_sharded(key, fitness, problem.n_genes, mesh,
                                      nsga_cfg,
                                      seed_genes=_seed_genes(problem, cfg))
        else:
            state = nsga2.init_state(key, fitness, problem.n_genes, nsga_cfg,
                                     seed_genes=_seed_genes(problem, cfg))
        n_evals += cfg.pop_size
        n_dispatches += 1

    if mesh is not None:
        make_chunk_fn = lambda n: dist.make_sharded_chunk(
            fitness, mesh, nsga_cfg, n)
    else:
        make_chunk_fn = lambda n: jax.jit(nsga2.make_chunk(
            fitness, nsga_cfg, n))
    # no out_dir -> nothing to save, so don't let checkpoint_every shrink
    # the chunks (the whole run stays one dispatch)
    saving = bool(ckpt_dir and cfg.checkpoint_every)
    state, cur_gen, n_chunks = _drive_chunks(
        state, start_gen, cfg.n_generations,
        cfg.checkpoint_every if saving else 0,
        make_chunk_fn,
        (lambda gen, s: checkpoint.save(ckpt_dir, gen, s, meta=meta))
        if saving else None)
    n_evals += cfg.pop_size * (cur_gen - start_gen)
    n_dispatches += n_chunks
    return state, n_evals, n_dispatches


def _islands_template(problem: SearchProblem, n_islands: int, local_pop: int):
    """Island NSGA2State skeleton (key axis = islands) for checkpoint.restore."""
    p = n_islands * local_pop
    return nsga2.NSGA2State(
        genes=jnp.zeros((p, problem.n_genes), jnp.float32),
        objs=jnp.zeros((p, 2), jnp.float32),
        rank=jnp.zeros((p,), jnp.int32),
        crowd=jnp.zeros((p,), jnp.float32),
        key=jnp.zeros((n_islands, 2), jnp.uint32),
        generation=jnp.int32(0),
    )


def _run_islands(problem: SearchProblem, cfg: SearchConfig):
    """Island driver: one NSGA-II island per device, ring migration.

    Generations are rounded UP to whole migration rounds (migrate_every
    each), so the islands backend may run slightly more generations than
    configured; `n_evaluations` reports what actually ran. Rounds execute as
    `dist.make_island_chunk` scans sized to the checkpoint cadence
    (DESIGN.md §9): checkpoints land on round boundaries, every
    ceil(checkpoint_every / migrate_every) rounds, labeled in generations;
    `resume=True` restores the gathered island state through
    `runtime.checkpoint` and re-shards it onto the current mesh."""
    from repro.core import dist
    from repro.launch.mesh import make_search_mesh
    from repro.runtime import checkpoint

    from repro.families import family_of

    fitness = family_of(problem).make_fitness(problem, "reference")
    # one mesh constructor for every driver (DESIGN.md §13); islands default
    # to a ring over all host devices when --mesh is unset
    mesh = make_search_mesh(cfg.mesh or "auto", axes=("data",))
    n_islands = mesh.shape["data"]
    local_pop = max(8, cfg.pop_size // max(n_islands, 1))
    island_cfg = dist.IslandConfig(
        local_pop=local_pop,
        migrate_every=cfg.migrate_every,
        n_migrate=min(cfg.n_migrate, local_pop // 2),
        nsga=nsga2.NSGA2Config(pop_size=local_pop,
                               n_generations=cfg.n_generations),
    )
    n_rounds = max(1, -(-cfg.n_generations // cfg.migrate_every))
    ckpt_rounds = (max(1, -(-cfg.checkpoint_every // cfg.migrate_every))
                   if cfg.checkpoint_every else 0)

    state = None
    start_round = 0
    n_evals = 0
    n_dispatches = 0
    ckpt_dir = _ckpt_dir(cfg)
    meta = {"family": "islands", "backend": cfg.backend,
            "pop_size": cfg.pop_size, "local_pop": local_pop,
            "n_islands": n_islands, "migrate_every": cfg.migrate_every}
    if cfg.resume and ckpt_dir:
        step = checkpoint.latest_step(ckpt_dir)
        if step is not None:
            saved_meta = _validate_resume_meta(ckpt_dir, step, "islands", cfg)
            if saved_meta.get("migrate_every", cfg.migrate_every) != cfg.migrate_every:
                raise ValueError(
                    f"islands checkpoint at step {step} was written with "
                    f"migrate_every={saved_meta['migrate_every']}; resuming "
                    f"with migrate_every={cfg.migrate_every} would shift the "
                    f"round grid")
            if saved_meta.get("n_islands", n_islands) != n_islands:
                raise ValueError(
                    f"islands checkpoint at step {step} was written on "
                    f"{saved_meta['n_islands']} islands; this host has "
                    f"{n_islands} devices (per-island populations would not "
                    f"line up)")
            state, gens_done = checkpoint.restore(
                ckpt_dir, step, _islands_template(problem, n_islands, local_pop),
                shardings=dist.island_state_sharding(mesh))
            start_round = gens_done // cfg.migrate_every

    if state is None:
        state = dist.init_islands(jax.random.PRNGKey(cfg.seed), fitness,
                                  problem.n_genes, mesh, island_cfg,
                                  seed_genes=_seed_genes(problem, cfg))
        n_evals += n_islands * local_pop
        n_dispatches += 1

    saving = bool(ckpt_dir and ckpt_rounds)
    state, cur_round, n_chunks = _drive_chunks(
        state, start_round, n_rounds, ckpt_rounds if saving else 0,
        lambda n: dist.make_island_chunk(fitness, mesh, island_cfg, n),
        (lambda rnd, s: checkpoint.save(
            ckpt_dir, rnd * cfg.migrate_every, s, meta=meta))
        if saving else None)
    n_evals += (n_islands * local_pop
                * (cur_round - start_round) * cfg.migrate_every)
    n_dispatches += n_chunks
    return state, n_evals, n_dispatches


def run_search(problem: SearchProblem, cfg: SearchConfig | None = None,
               **overrides) -> SearchResult:
    """One entry point for every search scenario.

    `overrides` are applied on top of `cfg` (or a default SearchConfig), so
    `run_search(problem, backend="kernel", pop_size=128)` works without
    building a config first.
    """
    cfg = dataclasses.replace(cfg or SearchConfig(), **overrides)
    if cfg.backend not in _backends.BACKENDS:
        raise ValueError(
            f"unknown backend {cfg.backend!r}; options: {_backends.BACKENDS}")
    if cfg.checkpoint_every < 0:
        raise ValueError(
            f"checkpoint_every must be >= 0, got {cfg.checkpoint_every}")
    if (cfg.emit_rtl or cfg.verify_rtl) and not cfg.out_dir:
        raise ValueError("emit_rtl/verify_rtl require out_dir")

    t0 = time.time()
    if cfg.backend == "islands":
        state, n_evals, n_dispatches = _run_islands(problem, cfg)
    else:
        from repro.launch.mesh import make_search_mesh

        kw = {}
        if cfg.backend == "kernel":
            kw = dict(block_p=cfg.block_p, block_b=cfg.block_b,
                      block_l=cfg.block_l, interpret=cfg.interpret)
        fitness = _backends.make_fitness(problem, cfg.backend, **kw)
        mesh = make_search_mesh(cfg.mesh, axes=("pop",))
        state, n_evals, n_dispatches = _run_single(problem, cfg, fitness,
                                                   mesh=mesh)
    wall_s = time.time() - t0

    objs, genes = nsga2.pareto_front(jax.device_get(state.objs),
                                     jax.device_get(state.genes))
    result = SearchResult(
        state=state,
        pareto_objs=np.asarray(objs),
        pareto_genes=np.asarray(genes),
        backend=cfg.backend,
        wall_s=wall_s,
        n_evaluations=n_evals,
        n_dispatches=n_dispatches,
    )
    if cfg.out_dir:
        from repro.families import family_of

        family_of(problem).write_artifact(
            problem, result, cfg.out_dir, emit_rtl=cfg.emit_rtl,
            verify_rtl=cfg.verify_rtl, dataset=cfg.dataset)
    return result


def _make_kernel_predict(problem: SearchProblem):
    """Single-chromosome (3N+1,) -> (B,) predictions through the Pallas path —
    the third leg of the RTL verification triangle (DESIGN.md §10). The
    decode folds comparator truncation into the effective operands and the
    vote cap models the approximate vote adder (DESIGN.md §16)."""
    from repro.kernels import ops as kops

    operands = kops.prepare_operands(
        problem.feature, problem.path, problem.path_len, problem.n_neg,
        problem.leaf_class, problem.n_classes, problem.n_features)

    def predict(genes):
        scale, thr, vote_cap = kops.decode_population(
            problem.threshold, genes[None, :])
        return kops.tree_infer_predict(problem.x8, operands, scale, thr,
                                       vote_cap)[0]

    return predict


def netlist_area_ratios(points) -> list[float]:
    """Per-point netlist/LUT area ratio from `pareto.json` points — the
    paper's Fig. 5 estimated-vs-actual gap (DESIGN.md §10). Points whose
    LUT estimate is zero (degenerate constant-false designs) are skipped."""
    return [p["area_netlist_mm2"] / p["area_mm2"] for p in points
            if p["area_mm2"] > 0]


def write_pareto_artifact(problem: SearchProblem, result: SearchResult,
                          out_dir: str, *, emit_rtl: bool = False,
                          verify_rtl: bool = False,
                          dataset: str | None = None) -> str:
    """pareto.json: objectives + genes + decoded designs + hardware artifact.

    Every point records the decoded `bits`/`margin` AND the substituted
    integer thresholds `t_int` — both PRE-truncation — plus the per-comparator
    `trunc` LSB-drop counts and the `vote_adder` mode (DESIGN.md §16), the
    top-level trained float `threshold` array AND the full super-tree leaf
    layout (`path`, `path_len`, `n_neg`, `leaf_class`), so a design
    re-materializes into RTL or a serving runtime from the artifact alone
    (`search.load_pareto_artifact`, DESIGN.md §14);
    the additive-LUT `area_mm2` estimate is paired with the
    synthesized-netlist `area_netlist_mm2` (gate counts after CSE/constant
    propagation) — the paper's Fig. 5 estimated-vs-actual gap as a measured
    artifact. The payload round-trips through the shared
    `search.artifact` schema validation, so writer and loader cannot drift.

    emit_rtl: write each point's Verilog (tree or forest) under OUT/rtl/.
    verify_rtl: simulate each point's netlist over the full test set and
    assert bit-exactness against `predict_votes` and the kernel backend.
    dataset: optional dataset label recorded for the serving CLI.
    """
    from repro.core import netlist, rtl
    from repro.search import artifact as _artifact
    from repro.search.problem import predict_votes, problem_ptrees

    os.makedirs(out_dir, exist_ok=True)
    ptrees = problem_ptrees(problem)
    if emit_rtl:
        os.makedirs(os.path.join(out_dir, "rtl"), exist_ok=True)
    kernel_predict = _make_kernel_predict(problem) if verify_rtl else None

    points = []
    for i, (o, g) in enumerate(zip(result.pareto_objs, result.pareto_genes)):
        g_j = jnp.asarray(g)
        bits_j, margin, trunc_j, vote_j = quant.decode_tree_genes(g_j)
        t_sub_j = quant.substitute(
            quant.threshold_to_int(problem.threshold, bits_j), margin, bits_j)
        bits = np.asarray(bits_j)
        t_sub = np.asarray(t_sub_j)
        trunc = np.asarray(trunc_j)
        vote_adder = "approx" if int(vote_j) else "exact"
        circuit = netlist.build_circuit(ptrees, bits, t_sub,
                                        problem.n_classes, trunc=trunc,
                                        vote_adder=vote_adder)
        point = {
            "acc_loss": float(o[0]),
            "norm_area": float(o[1]),
            "area_mm2": float(o[1] * problem.exact_area_mm2),
            "area_netlist_mm2": round(netlist.netlist_area_mm2(circuit), 4),
            "netlist_gates": netlist.gate_counts(circuit),
            "bits": bits.tolist(),
            "margin": np.asarray(margin).tolist(),
            "t_int": t_sub.tolist(),
            "trunc": trunc.tolist(),
            "vote_adder": vote_adder,
            "genes": np.asarray(g, np.float64).round(6).tolist(),
        }
        if emit_rtl:
            verilog = rtl.emit_design(ptrees, bits, t_sub, problem.n_classes,
                                      trunc=trunc, vote_adder=vote_adder)
            rel = os.path.join("rtl", f"point_{i:02d}.v")
            with open(os.path.join(out_dir, rel), "w") as f:
                f.write(verilog)
            point["rtl"] = rel
        if verify_rtl:
            vote_cap = jnp.where(vote_j > 0, jnp.float32(1.0),
                                 jnp.float32(jnp.inf))
            sim = np.asarray(netlist.simulate(circuit, problem.x8))
            ref = np.asarray(predict_votes(
                problem, bits_j - trunc_j, jnp.right_shift(t_sub_j, trunc_j),
                vote_cap))
            ker = np.asarray(kernel_predict(g_j))
            if not (np.array_equal(sim, ref) and np.array_equal(sim, ker)):
                n_ref = int((sim != ref).sum())
                n_ker = int((sim != ker).sum())
                raise AssertionError(
                    f"pareto point {i}: netlist simulation diverges from "
                    f"predict_votes on {n_ref} and from the kernel backend "
                    f"on {n_ker} of {sim.shape[0]} test samples")
            point["verified"] = True
        points.append(point)

    payload = {
        "family": "tree",
        "backend": result.backend,
        "wall_s": round(result.wall_s, 3),
        "n_evaluations": result.n_evaluations,
        "n_dispatches": result.n_dispatches,
        "n_trees": problem.n_trees,
        "n_comparators": problem.n_comparators,
        "n_classes": problem.n_classes,
        "tree_comparators": list(problem.tree_comparators),
        "tree_leaves": list(problem.tree_leaves),
        "feature": np.asarray(problem.feature).tolist(),
        "threshold": np.asarray(problem.threshold, np.float64)
                       .round(8).tolist(),
        "path": np.asarray(problem.path).tolist(),
        "path_len": np.asarray(problem.path_len).tolist(),
        "n_neg": np.asarray(problem.n_neg).tolist(),
        "leaf_class": np.asarray(problem.leaf_class).tolist(),
        "exact_accuracy": problem.exact_accuracy,
        "exact_area_mm2": problem.exact_area_mm2,
        "rtl_verified": bool(verify_rtl),
        "pareto": points,
    }
    if dataset is not None:
        payload["dataset"] = dataset
    _artifact.validate_payload(payload, where="write_pareto_artifact")
    path = os.path.join(out_dir, "pareto.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)
    return path
