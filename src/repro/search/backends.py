"""Pluggable fitness backends over a `SearchProblem` (DESIGN.md §7, §12).

Every backend maps a population of real-coded genes (P, n_genes) — for
trees the cross-layer (P, 3N+1) layout of DESIGN.md §16 — to objectives
(P, 2) = (accuracy loss vs exact design, normalized area), bit-compatible
with each other:

  reference — pure-jnp vmap of the block-diagonal super-tree dataflow; the
              portable oracle (and what `core.approx.make_fitness_fn`
              historically computed for K=1). Rides the hoisted fitness
              pipeline (§12): the chromosome-invariant feature gather is
              precomputed on the problem (`SearchProblem.x_sel`) and ONE
              gene decode feeds both objectives.
  kernel    — the fused Pallas *fitness* kernel (`kernels.fitness`): the
              whole population x test-set x forest evaluation is ONE launch
              (grid = pop-blocks x batch-blocks x leaf-blocks, `block_p`
              chromosomes per cell), votes -> argmax -> label-compare happen
              inside the kernel, and only the O(P) per-chromosome error
              counts reach HBM — the (P, B, C) vote tensor the historical
              `tree_infer_scores` path materialized stays on-chip. That
              scores path remains the bit-exact materializing oracle
              (`kernels.ops.tree_infer_predict`, asserted in tests and used
              by the §10 RTL verification triangle).
  islands   — not a fitness function but a *driver* strategy (per-device
              NSGA-II islands with ring migration, `core.dist`); it reuses
              the reference fitness per island, is selected through
              `repro.search.engine.run_search`, and shares the engine's
              chunked-scan checkpoint/resume machinery (DESIGN.md §9).

The accuracy term of `reference` and `kernel` agree bit-exactly: every
integer quantity is exact in f32 (< 2^24), the kernel's on-chip reductions
add small exact integers, and both divide the same exact correct count by
the same sample count (see `repro.kernels.fitness`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.search.problem import SearchProblem, objectives

BACKENDS = ("reference", "kernel", "islands")


def make_reference_fitness(problem: SearchProblem):
    """Population fitness: (P, n_genes) genes -> (P, 2) objectives, jitted."""

    @jax.jit
    def fitness(pop):
        return jax.vmap(functools.partial(objectives, problem))(pop)

    return fitness


def make_kernel_fitness(problem: SearchProblem, *, block_p: int = 8,
                        block_b: int = 256, block_l: int | None = None,
                        interpret: bool | None = None):
    """Kernel-backed fitness: accuracy via ONE fused Pallas launch for the
    entire (population x test-set x forest) product, area via the LUT gather.
    Same objectives as `make_reference_fitness` — asserted equal in tests.

    `block_p` tiles the population axis (DESIGN.md §12): each grid cell
    evaluates a (block_p, N) slab of chromosomes against a (block_b, N)
    batch tile, amortizing the static operands over the slab and keeping
    the VPU sublanes dense.
    """
    from repro.kernels import ops as kops  # local import: kernels are optional

    # problem.path is already the block-diagonal super-tree layout;
    # problem.x_sel is the feature gather, hoisted once at problem build —
    # the kernel never re-runs it per grid cell (§12).
    fit_operands = kops.prepare_fitness_operands(
        problem.x_sel, problem.y, problem.path, problem.path_len,
        problem.n_neg, problem.leaf_class, problem.n_classes)
    threshold = problem.threshold
    n_samples = jnp.float32(problem.y.shape[0])

    @jax.jit
    def fitness(pop):
        # ONE decode feeds the kernel operands AND the area LUT index
        # (historically this decoded twice per eval). Truncation is already
        # folded into the effective (scale, t_sub, bits) and the vote cap
        # rides into the kernel's on-chip argmax (DESIGN.md §16).
        scale, t_sub, bits, vote_cap = kops.decode_population_full(
            threshold, pop)
        errors = kops.fitness_errors(
            fit_operands, scale, t_sub.astype(jnp.float32), vote_cap,
            block_p=block_p, block_b=block_b, block_l=block_l,
            interpret=interpret)
        acc = (n_samples - errors) / n_samples
        areas = problem.area_lut[problem.lut_offsets[bits] + t_sub].sum(axis=1)
        areas = areas + problem.overhead_mm2
        areas = areas + jnp.where(jnp.isfinite(vote_cap),
                                  jnp.float32(problem.vote_mm2_approx),
                                  jnp.float32(problem.vote_mm2_exact))
        return jnp.stack(
            [problem.exact_accuracy - acc, areas / problem.exact_area_mm2],
            axis=1,
        )

    return fitness


def make_fitness(problem, backend: str = "reference", **kw):
    """Factory: backend name -> population fitness function.

    Family-agnostic: `SearchProblem`s take the tree routes above; any other
    registered family's problem dispatches to that family's own
    `make_fitness` (DESIGN.md §15) so `engine.run_search` stays generic.
    """
    if backend not in ("reference", "kernel"):
        raise ValueError(
            f"unknown fitness backend {backend!r}; islands is driver-level "
            f"(use repro.search.engine.run_search), options: {BACKENDS}")
    if isinstance(problem, SearchProblem):
        if backend == "reference":
            return make_reference_fitness(problem)
        return make_kernel_fitness(problem, **kw)
    from repro.families import family_of
    return family_of(problem).make_fitness(problem, backend, **kw)
