"""Pluggable fitness backends over a `SearchProblem` (DESIGN.md §7).

Every backend maps a population of real-coded genes (P, 2N) to objectives
(P, 2) = (accuracy loss vs exact design, normalized area), bit-compatible
with each other:

  reference — pure-jnp vmap of the block-diagonal super-tree dataflow; the
              portable oracle (and what `core.approx.make_fitness_fn`
              historically computed for K=1).
  kernel    — the fused Pallas `tree_infer` program: the whole
              population x test-set x forest evaluation is ONE kernel launch
              (grid = population x batch-blocks x leaf-blocks), replacing
              the K-iteration per-tree Python loop of the old forest path.
  islands   — not a fitness function but a *driver* strategy (per-device
              NSGA-II islands with ring migration, `core.dist`); it reuses
              the reference fitness per island, is selected through
              `repro.search.engine.run_search`, and shares the engine's
              chunked-scan checkpoint/resume machinery (DESIGN.md §9).

The accuracy term of `reference` and `kernel` agree bit-exactly: every
integer quantity is exact in f32 (< 2^24) and vote accumulation adds small
exact integers (see `repro.kernels.tree_infer`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.search.problem import SearchProblem, objectives

BACKENDS = ("reference", "kernel", "islands")


def make_reference_fitness(problem: SearchProblem):
    """Population fitness: (P, 2N) genes -> (P, 2) objectives, jitted."""

    @jax.jit
    def fitness(pop):
        return jax.vmap(functools.partial(objectives, problem))(pop)

    return fitness


def make_kernel_fitness(problem: SearchProblem, *, block_b: int = 256,
                        block_l: int | None = None,
                        interpret: bool | None = None):
    """Kernel-backed fitness: accuracy via ONE fused Pallas launch for the
    entire (population x test-set x forest) product, area via the LUT gather.
    Same objectives as `make_reference_fitness` — asserted equal in tests."""
    from repro.kernels import ops as kops  # local import: kernels are optional

    # problem.path is already the block-diagonal super-tree layout.
    operands = kops.prepare_operands(
        problem.feature, problem.path, problem.path_len, problem.n_neg,
        problem.leaf_class, problem.n_classes, problem.n_features)
    threshold = problem.threshold

    @jax.jit
    def fitness(pop):
        scale, thr = kops.decode_population(threshold, pop)
        preds = kops.tree_infer_predict(problem.x8, operands, scale, thr,
                                        block_b=block_b, block_l=block_l,
                                        interpret=interpret)
        acc = jnp.mean((preds == problem.y[None, :]).astype(jnp.float32), axis=1)
        bits, margin = quant.decode_genes(pop)
        t_int = quant.threshold_to_int(threshold[None, :], bits)
        t_sub = quant.substitute(t_int, margin, bits)
        areas = problem.area_lut[problem.lut_offsets[bits] + t_sub].sum(axis=1)
        areas = areas + problem.overhead_mm2
        return jnp.stack(
            [problem.exact_accuracy - acc, areas / problem.exact_area_mm2],
            axis=1,
        )

    return fitness


def make_fitness(problem: SearchProblem, backend: str = "reference", **kw):
    """Factory: backend name -> population fitness function."""
    if backend == "reference":
        return make_reference_fitness(problem)
    if backend == "kernel":
        return make_kernel_fitness(problem, **kw)
    raise ValueError(
        f"unknown fitness backend {backend!r}; islands is driver-level "
        f"(use repro.search.engine.run_search), options: {BACKENDS}")
