"""Robustness campaigns over Pareto artifacts (DESIGN.md §17).

Turns the raw fault-lane machinery of `core.faults` into the per-design
question a printed-circuit campaign actually asks: *for each Pareto point,
what accuracy survives fabrication defects?* Three metrics per point:

  - **exhaustive single stuck-at**: every fault site x {stuck-0, stuck-1}
    re-classifies the full test split in one chunked vmapped program;
    reported as the mean/worst accuracy and drop vs the defect-free design.
  - **Monte-Carlo defect draws**: `n_trials` iid gate-defect masks at
    `defect_rate` per site (stuck polarity a fair coin), each trial keyed
    by `jax.random.fold_in(key(seed), trial)` so a fixed seed reproduces
    the report bit-for-bit.
  - **critical-gate ranking**: sites ordered by their worst-polarity
    accuracy drop — where redundancy or upsizing buys the most yield.

Results go to `fault_report.json` under the same two-sided key discipline
as `search/artifact.py`: `validate_fault_report` rejects missing AND
unknown keys with a named `ValueError`, and runs on write and on load.
The campaign is family-agnostic — any artifact whose family implements
`build_point_circuit` (trees/forests and printed MLPs alike) works.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import faults, netlist

DEFAULT_DEFECT_RATE = 0.02
DEFAULT_TRIALS = 32
DEFAULT_TOP_K = 10
DEFAULT_MC_SEED = 0

# fault_report.json writer/loader contract (mirrors search.artifact: the
# schema may only grow by extending these sets; both directions are errors)
REQUIRED_TOP_KEYS = frozenset({
    "source", "dataset", "family", "n_classes", "n_samples",
    "defect_rate", "n_trials", "mc_seed", "top_k", "points",
})
OPTIONAL_TOP_KEYS = frozenset({"max_loss"})
REQUIRED_POINT_KEYS = frozenset({
    "point", "acc_loss", "norm_area", "area_mm2", "n_gates", "n_sites",
    "n_faults", "baseline_accuracy", "recorded_accuracy",
    "zero_fault_matches_simulate", "single_fault", "critical_gates",
    "monte_carlo",
})
REQUIRED_SINGLE_FAULT_KEYS = frozenset({
    "mean_accuracy", "worst_accuracy", "mean_drop", "worst_drop",
})
REQUIRED_MC_KEYS = frozenset({
    "expected_accuracy", "std_accuracy", "worst_accuracy",
    "mean_faulty_sites",
})
REQUIRED_CRITICAL_KEYS = frozenset({
    "gate", "label", "kind", "drop", "stuck_value",
})


def _check_keys(have, required, optional, where: str) -> None:
    have = set(have)
    missing = sorted(required - have)
    unknown = sorted(have - required - optional)
    problems = []
    if missing:
        problems.append(f"missing keys {missing}")
    if unknown:
        problems.append(f"unknown keys {unknown}")
    if problems:
        raise ValueError(
            f"fault report {where}: {'; '.join(problems)} "
            f"(expected {sorted(required)} + optional {sorted(optional)})")


def validate_fault_report(payload: dict, where: str = "payload") -> dict:
    """Two-sided schema check for a fault_report.json payload.

    Missing and unknown keys both raise a named `ValueError` (top level,
    per point, and the nested single_fault / monte_carlo / critical_gates
    records), plus the campaign invariants: `n_faults == 2 * n_sites` and
    a zero-fault lane that matched `netlist.simulate` exactly.
    """
    if not isinstance(payload, dict):
        raise ValueError(f"fault report {where}: expected a JSON object, "
                         f"got {type(payload).__name__}")
    _check_keys(payload, REQUIRED_TOP_KEYS, OPTIONAL_TOP_KEYS, where)
    if not isinstance(payload["points"], list):
        raise ValueError(f"fault report {where}: 'points' must be a list")
    for i, point in enumerate(payload["points"]):
        w = f"{where}.points[{i}]"
        if not isinstance(point, dict):
            raise ValueError(f"fault report {w}: must be an object")
        _check_keys(point, REQUIRED_POINT_KEYS, frozenset(), w)
        _check_keys(point["single_fault"], REQUIRED_SINGLE_FAULT_KEYS,
                    frozenset(), f"{w}.single_fault")
        _check_keys(point["monte_carlo"], REQUIRED_MC_KEYS, frozenset(),
                    f"{w}.monte_carlo")
        for j, cg in enumerate(point["critical_gates"]):
            _check_keys(cg, REQUIRED_CRITICAL_KEYS, frozenset(),
                        f"{w}.critical_gates[{j}]")
        if point["n_faults"] != 2 * point["n_sites"]:
            raise ValueError(
                f"fault report {w}: n_faults={point['n_faults']} is not "
                f"2 * n_sites={point['n_sites']} (stuck-0 + stuck-1 lanes)")
        if not point["zero_fault_matches_simulate"]:
            raise ValueError(
                f"fault report {w}: zero_fault_matches_simulate is false — "
                f"the fault simulator diverged from core.netlist.simulate")
    return payload


def single_stuck_at(sim: faults.FaultSimulator, x8, y,
                    chunk: int | None = None):
    """Exhaustive single stuck-at campaign: accuracies of every fault.

    Returns (sites, accuracies) where `accuracies` is (2S,) float64 with
    lane 2k = site k stuck-at-0 and lane 2k+1 = stuck-at-1 (the lane order
    of `faults.single_fault_lanes`).
    """
    y = np.asarray(y, np.int64)
    sites = faults.enumerate_fault_sites(sim.circuit)
    gates, values = faults.single_fault_lanes(sim.circuit, sites)
    preds = sim.run_sites(x8, gates, values, chunk=chunk)   # (2S, B)
    accs = (preds == y[None, :]).mean(axis=1)
    return sites, accs


def monte_carlo(sim: faults.FaultSimulator, x8, y, *,
                defect_rate: float = DEFAULT_DEFECT_RATE,
                n_trials: int = DEFAULT_TRIALS,
                seed: int = DEFAULT_MC_SEED,
                chunk: int | None = None) -> dict:
    """Expected accuracy under iid per-site defects (fixed PRNG keys).

    Each trial `t` draws its defect mask and stuck polarities from
    `fold_in(key(seed), t)` — re-running with the same seed reproduces
    every mask, so the report is bit-for-bit deterministic. Returns the
    metric dict plus the per-trial accuracy array under "_accuracies"
    (stripped before serialization).
    """
    import jax

    y = np.asarray(y, np.int64)
    sites = faults.enumerate_fault_sites(sim.circuit)
    site_gates = np.asarray([s.gate for s in sites], np.int64)
    g = sim.circuit.n_gates
    base = jax.random.key(seed)
    mask = np.zeros((n_trials, g), bool)
    val = np.zeros((n_trials, g), bool)
    for t in range(n_trials):
        k_hit, k_pol = jax.random.split(jax.random.fold_in(base, t))
        hit = np.asarray(jax.random.bernoulli(
            k_hit, defect_rate, (len(sites),)))
        pol = np.asarray(jax.random.bernoulli(k_pol, 0.5, (len(sites),)))
        mask[t, site_gates[hit]] = True
        val[t, site_gates[hit]] = pol[hit]
    preds = sim.run_masks(x8, mask, val, chunk=chunk)       # (T, B)
    accs = (preds == y[None, :]).mean(axis=1)
    return {
        "expected_accuracy": float(accs.mean()),
        "std_accuracy": float(accs.std()),
        "worst_accuracy": float(accs.min()),
        "mean_faulty_sites": float(mask.sum(axis=1).mean()),
        "_accuracies": accs,
    }


def critical_gates(sites, accs, baseline: float,
                   top_k: int = DEFAULT_TOP_K) -> list:
    """Top-k sites by worst-polarity accuracy drop, largest first.

    Ties break on gate id so the ranking is deterministic.
    """
    accs = np.asarray(accs, np.float64).reshape(-1, 2)   # (S, [sa0, sa1])
    worst_pol = accs.argmin(axis=1)                      # 0 = stuck-at-0
    drops = baseline - accs.min(axis=1)
    order = sorted(range(len(sites)), key=lambda i: (-drops[i],
                                                     sites[i].gate))
    return [{
        "gate": int(sites[i].gate),
        "label": sites[i].label,
        "kind": sites[i].kind,
        "drop": float(drops[i]),
        "stuck_value": int(worst_pol[i]),
    } for i in order[:top_k]]


def point_robustness(circuit, x8, y, *,
                     defect_rate: float = DEFAULT_DEFECT_RATE,
                     n_trials: int = DEFAULT_TRIALS,
                     seed: int = DEFAULT_MC_SEED,
                     top_k: int = DEFAULT_TOP_K,
                     chunk: int | None = None) -> dict:
    """All three robustness metrics for one circuit on one test split.

    The returned dict carries the per-point schema fields that do not
    depend on the artifact (`run_campaign` adds point/acc_loss/norm_area/
    area_mm2/recorded_accuracy).
    """
    y = np.asarray(y, np.int64)
    sim = faults.FaultSimulator(circuit)
    zero = sim.run_zero_fault(x8)
    oracle = np.asarray(netlist.simulate(circuit, x8))
    zero_ok = bool(np.array_equal(zero, oracle))
    baseline = float((zero == y).mean())
    sites, accs = single_stuck_at(sim, x8, y, chunk=chunk)
    mc = monte_carlo(sim, x8, y, defect_rate=defect_rate,
                     n_trials=n_trials, seed=seed, chunk=chunk)
    mc.pop("_accuracies")
    return {
        "n_gates": int(circuit.n_gates),
        "n_sites": len(sites),
        "n_faults": int(accs.shape[0]),
        "baseline_accuracy": baseline,
        "zero_fault_matches_simulate": zero_ok,
        "single_fault": {
            "mean_accuracy": float(accs.mean()),
            "worst_accuracy": float(accs.min()),
            "mean_drop": float((baseline - accs).mean()),
            "worst_drop": float((baseline - accs).max()),
        },
        "critical_gates": critical_gates(sites, accs, baseline,
                                         top_k=top_k),
        "monte_carlo": mc,
    }


def select_points(artifact, point: str = "all",
                  max_loss: float = 0.01) -> list[int]:
    """Resolve a --point spec: 'all', 'best' (smallest area within
    `max_loss`), or an explicit index."""
    n = len(artifact.points)
    if point == "all":
        return list(range(n))
    if point == "best":
        best = artifact.best_under_loss(max_loss)
        if best is None:
            raise ValueError(
                f"fault campaign: no pareto point within max_loss="
                f"{max_loss} (have {n} points)")
        return [best]
    idx = int(point)
    if not -n <= idx < n:
        raise ValueError(f"fault campaign: point index {idx} out of range "
                         f"for {n} pareto points")
    return [idx % n]


def run_campaign(artifact, x8, y, *, source: str = "pareto.json",
                 dataset: str | None = None, point: str = "all",
                 max_loss: float = 0.01,
                 defect_rate: float = DEFAULT_DEFECT_RATE,
                 n_trials: int = DEFAULT_TRIALS,
                 seed: int = DEFAULT_MC_SEED,
                 top_k: int = DEFAULT_TOP_K,
                 chunk: int | None = None,
                 verbose: bool = False) -> dict:
    """Per-Pareto-point robustness report for one artifact (any family).

    Builds each selected point's gate-level circuit through its family's
    `build_point_circuit`, runs the three campaigns of `point_robustness`,
    and returns a validated fault_report payload.
    """
    from repro.families import get_family

    family = getattr(artifact, "family", "tree")
    fam = get_family(family)
    points = []
    for idx in select_points(artifact, point, max_loss):
        circuit = fam.build_point_circuit(artifact, idx)
        row = point_robustness(circuit, x8, y, defect_rate=defect_rate,
                               n_trials=n_trials, seed=seed, top_k=top_k,
                               chunk=chunk)
        pt = artifact.points[idx]
        row = {
            "point": int(idx),
            "acc_loss": float(pt["acc_loss"]),
            "norm_area": float(pt["norm_area"]),
            "area_mm2": float(pt.get("area_netlist_mm2",
                                     pt.get("area_mm2", 0.0))),
            "recorded_accuracy": float(artifact.point_accuracy(idx)),
            **row,
        }
        points.append(row)
        if verbose:
            sf = row["single_fault"]
            print(f"  point {idx}: {row['n_sites']} sites x 2 faults, "
                  f"baseline {row['baseline_accuracy']:.4f}, 1-fault "
                  f"mean {sf['mean_accuracy']:.4f} / worst "
                  f"{sf['worst_accuracy']:.4f}, MC({defect_rate:.0%}) "
                  f"{row['monte_carlo']['expected_accuracy']:.4f}")
    payload = {
        "source": source,
        "dataset": dataset if dataset is not None
        else getattr(artifact, "dataset", None),
        "family": family,
        "n_classes": int(artifact.n_classes),
        "n_samples": int(np.asarray(x8).shape[0]),
        "defect_rate": float(defect_rate),
        "n_trials": int(n_trials),
        "mc_seed": int(seed),
        "top_k": int(top_k),
        "max_loss": float(max_loss),
        "points": points,
    }
    return validate_fault_report(payload)


def write_fault_report(payload: dict, path: str) -> str:
    """Validate + atomically write a fault_report.json."""
    validate_fault_report(payload, where=path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)
    return path


def load_fault_report(path: str) -> dict:
    """Load + validate a fault_report.json."""
    with open(path) as f:
        payload = json.load(f)
    return validate_fault_report(payload, where=path)
