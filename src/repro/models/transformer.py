"""Model assembly: embeddings, scanned layer stacks, caches, decode.

One code path serves all 10 assigned architectures:

  dense/audio/vlm  : [attn + mlp] x L            (scan over stacked params)
  moe              : [attn + moe] x L            (+ load-balance aux loss)
  ssm              : [mamba2] x L
  hybrid (zamba2)  : super-blocks of `shared_attn_every` mamba2 layers
                     followed by one of `n_shared_blocks` *shared* attn+mlp
                     blocks (alternating), + a tail of plain mamba2 layers

Layers are scanned (`lax.scan` over stacked params) to bound HLO size and
compile time at 48-81 layers; bodies are rematerialized when cfg.remat
(nothing_saveable policy — the residual stream itself is the only saved
activation, sequence-sharded over the model axis per DESIGN.md §5).
Caches: attention (L, B, S_max, KVe, hd) k/v pairs; SSM (L, B, K-1, C) conv +
(L, B, NH, HD, N) states; zamba additionally keeps per-invocation KV caches
for the shared blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, lm_mlp, moe, ssm
from repro.models.common import apply_norm, init_norm, normal_init
from repro.sharding.rules import head_sharding, maybe_shard


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def layer_kind(cfg) -> str:
    if cfg.family == "moe":
        return "attn_moe"
    if cfg.family in ("ssm", "hybrid"):
        return "ssm"
    return "attn_mlp"


def _init_attn_mlp(key, cfg, dtype, use_moe: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg, dtype),
        "attn": attention.init_attention(k1, cfg, dtype),
        "ln2": init_norm(cfg, dtype),
        "ffn": moe.init_moe(k2, cfg, dtype) if use_moe
        else lm_mlp.init_mlp(k3, cfg, dtype),
    }


def _init_layer(key, cfg, dtype):
    kind = layer_kind(cfg)
    if kind == "ssm":
        k1, _ = jax.random.split(key)
        return {"ln1": init_norm(cfg, dtype), "ssm": ssm.init_ssm(k1, cfg, dtype)}
    return _init_attn_mlp(key, cfg, dtype, use_moe=(kind == "attn_moe"))


def init_params(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 5)
    vp, d = cfg.padded_vocab, cfg.d_model
    params = {
        "embed": normal_init(keys[0], (vp, d), 0.02, dtype),
        "final_norm": init_norm(cfg, dtype),
    }
    layer_keys = jax.random.split(keys[1], cfg.n_layers)
    params["layers"] = jax.vmap(
        lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    if cfg.family == "hybrid" and cfg.n_shared_blocks:
        sh_keys = jax.random.split(keys[2], cfg.n_shared_blocks)
        params["shared"] = jax.vmap(
            lambda k: _init_attn_mlp(k, cfg, dtype, use_moe=False))(sh_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(keys[3], (vp, d), d ** -0.5, dtype)
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attn_mlp_block(p, cfg, x, positions, *, rules, mode, kv_repeat,
                    cache=None, cache_pos=None, cache_layer=None,
                    use_moe=False):
    """Returns (x_out, new_kv, aux_loss)."""
    h, new_kv = attention.attention_block(
        p["attn"], cfg, apply_norm(p["ln1"], x, cfg.norm), positions,
        mode=mode, kv_repeat=kv_repeat, rules=rules,
        cache=cache, cache_pos=cache_pos, cache_layer=cache_layer)
    x = x + h
    z = apply_norm(p["ln2"], x, cfg.norm)
    if use_moe:
        ff, aux = moe.moe_block(p["ffn"], cfg, z, rules)
    else:
        ff, aux = lm_mlp.mlp_block(p["ffn"], cfg, z, rules), jnp.float32(0.0)
    return x + ff, new_kv, aux


def _ssm_layer(p, cfg, x, *, rules, cache=None, cache_layer=None):
    h, new_cache = ssm.ssm_block(p["ssm"], cfg,
                                 apply_norm(p["ln1"], x, cfg.norm),
                                 rules=rules, cache=cache,
                                 cache_layer=cache_layer)
    return x + h, new_cache


def hybrid_layout(cfg):
    """(n_super, per_super, tail) decomposition of the zamba2 stack."""
    every = cfg.shared_attn_every
    n_super = cfg.n_layers // every
    return n_super, every, cfg.n_layers - n_super * every


def _tree_slice(tree, sl):
    return jax.tree.map(lambda a: a[sl], tree)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(params, cfg, tokens, *, rules=None, prefix_embed=None,
            caches=None, pos0=None):
    """Shared forward for train / prefill (caches=None: fresh caches are
    returned) and decode (caches given: one-token step at position pos0).

    tokens (B, S_text) int32; prefix_embed (B, P, D) for vlm.
    Returns (hidden (B, S, D), new_caches, aux_loss).
    """
    x = params["embed"][tokens]                    # gather (B, S_text, D)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape

    decoding = caches is not None
    if decoding:
        positions = jnp.zeros((b, 1), jnp.int32) + pos0
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    mode, kv_repeat = head_sharding(cfg, rules)
    seq_ok = (not decoding) and rules is not None and s % rules.tp == 0
    res_spec = None if rules is None else \
        (rules.batch, rules.seq if seq_ok else None, None)

    def shard_res(h):
        return maybe_shard(h, res_spec, rules) if res_spec else h

    x = shard_res(x)
    kind = layer_kind(cfg)
    new_caches = {}
    aux_total = jnp.float32(0.0)
    remat = cfg.remat and not decoding

    def maybe_remat(fn):
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.nothing_saveable) if remat else fn

    if kind in ("attn_mlp", "attn_moe"):
        use_moe = kind == "attn_moe"

        if decoding:
            # fori over layers, caches updated IN PLACE on the stacked
            # arrays (one tiny dynamic_update_slice per layer) — a scan
            # carrying caches as xs/ys would functionally copy them.
            def dec_body(l, carry):
                h, kv = carry
                p = _tree_slice(params["layers"], l)
                h, kv, _ = _attn_mlp_block(
                    p, cfg, h, positions, rules=rules, mode=mode,
                    kv_repeat=kv_repeat, cache=kv, cache_pos=pos0,
                    cache_layer=l, use_moe=use_moe)
                return (shard_res(h), kv)

            x, kv = jax.lax.fori_loop(0, cfg.n_layers, dec_body,
                                      (x, caches["kv"]))
            new_caches["kv"] = kv
        else:
            def body(carry, p):
                h, aux = carry
                h, kv, a = _attn_mlp_block(
                    p, cfg, h, positions, rules=rules, mode=mode,
                    kv_repeat=kv_repeat, use_moe=use_moe)
                return (shard_res(h), aux + a), kv

            (x, aux_total), kv = jax.lax.scan(
                maybe_remat(body), (x, aux_total), params["layers"])
            new_caches["kv"] = kv

    elif cfg.family == "ssm":
        if decoding:
            def dec_body(l, carry):
                h, c = carry
                p = _tree_slice(params["layers"], l)
                h, c = _ssm_layer(p, cfg, h, rules=rules, cache=c,
                                  cache_layer=l)
                return (shard_res(h), c)

            x, c = jax.lax.fori_loop(0, cfg.n_layers, dec_body,
                                     (x, caches["ssm"]))
            new_caches["ssm"] = c
        else:
            def body(h, p):
                h, c = _ssm_layer(p, cfg, h, rules=rules)
                return shard_res(h), c

            x, c = jax.lax.scan(maybe_remat(body), x, params["layers"])
            new_caches["ssm"] = c

    else:  # hybrid (zamba2)
        n_super, per_super, tail = hybrid_layout(cfg)

        if decoding:
            # flat caches: ssm over all n_layers, shared kv per invocation
            def ssm_at(l, carry):
                h, ssm_c, shared_kv = carry
                p = _tree_slice(params["layers"], l)
                h, ssm_c = _ssm_layer(p, cfg, h, rules=rules, cache=ssm_c,
                                      cache_layer=l)
                return (shard_res(h), ssm_c, shared_kv)

            def super_dec(sb, carry):
                carry = jax.lax.fori_loop(
                    sb * per_super, (sb + 1) * per_super, ssm_at, carry)
                h, ssm_c, shared_kv = carry
                shared_p = _tree_slice(params["shared"],
                                       sb % cfg.n_shared_blocks)
                h, shared_kv, _ = _attn_mlp_block(
                    shared_p, cfg, h, positions, rules=rules, mode=mode,
                    kv_repeat=kv_repeat, cache=shared_kv, cache_pos=pos0,
                    cache_layer=sb, use_moe=False)
                return (shard_res(h), ssm_c, shared_kv)

            carry = (x, caches["ssm"], caches["shared_kv"])
            carry = jax.lax.fori_loop(0, n_super, super_dec, carry)
            carry = jax.lax.fori_loop(n_super * per_super, cfg.n_layers,
                                      ssm_at, carry)
            x, ssm_c, shared_kv = carry
            new_caches["ssm"] = ssm_c
            new_caches["shared_kv"] = shared_kv
        else:
            main = _tree_slice(params["layers"], slice(0, n_super * per_super))
            main = jax.tree.map(
                lambda a: a.reshape(n_super, per_super, *a.shape[1:]), main)
            tail_p = _tree_slice(params["layers"],
                                 slice(n_super * per_super, cfg.n_layers))

            def inner(h, p):
                h, c = _ssm_layer(p, cfg, h, rules=rules)
                return shard_res(h), c

            def super_body(h, inp):
                p_grp, idx = inp
                h, ssm_c = jax.lax.scan(inner, h, p_grp)
                shared_p = _tree_slice(params["shared"],
                                       idx % cfg.n_shared_blocks)
                h, kv, _ = _attn_mlp_block(
                    shared_p, cfg, h, positions, rules=rules, mode=mode,
                    kv_repeat=kv_repeat, use_moe=False)
                return shard_res(h), (ssm_c, kv)

            idxs = jnp.arange(n_super)
            x, (ssm_c, kv) = jax.lax.scan(maybe_remat(super_body), x,
                                          (main, idxs))
            new_caches["ssm_main"] = ssm_c
            new_caches["shared_kv"] = kv
            if tail:
                x, tc = jax.lax.scan(maybe_remat(inner), x, tail_p)
                new_caches["ssm_tail"] = tc

    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, new_caches, aux_total


def logits_from_hidden(params, cfg, hidden):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,vd->bsv", hidden, table)
