"""Mixture-of-Experts: explicit expert-parallel dispatch (shard_map) on a
mesh, sort-based ragged-free routing, capacity drop.

Three code paths (DESIGN.md §5):

1. `_moe_local` (rules is None) — single-device reference: sort-based
   dispatch + three batched einsums. The oracle for the distributed paths.

2. EP **all-to-all** (`e % dp == 0`, kimi: 384 experts / 16 data shards):
   tokens are SP-all-gathered over the model axis, routed locally, exchanged
   to their expert's owner with ONE `lax.all_to_all` over the data axis,
   computed with (expert->data, d_ff->model)-sharded weights, exchanged
   back, and the partial (over model) outputs return to sequence-parallel
   layout with a single `psum_scatter`. This is the production EP pattern —
   the dispatch never materializes a (tokens, E, capacity) one-hot and no
   token buffer is ever replicated.

3. EP **gathered-weights** (few experts, grok: 8 experts < 16 shards):
   every (data, model) rank keeps its own (batch x seq)-sharded tokens and
   transiently all-gathers the (d_ff over data x model)-sharded expert
   weights (ZeRO-3 style, 2-3 layer-sized all-gathers per block); no token
   movement at all. Chosen when the expert count cannot tile the mesh.

Gradients flow through both paths (all_to_all / all_gather transpose to
all_to_all / psum_scatter under AD).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.common import activation, is_glu, normal_init
from repro.sharding.rules import maybe_shard


def init_moe(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "router": normal_init(k1, (d, e), d ** -0.5, jnp.float32),
        "wi": normal_init(k2, (e, d, ff), d ** -0.5, dtype),
        "wo": normal_init(k3, (e, ff, d), ff ** -0.5, dtype),
    }
    if is_glu(cfg.act):
        p["wg"] = normal_init(k4, (e, d, ff), d ** -0.5, dtype)
    return p


# ---------------------------------------------------------------------------
# shared routing pieces
# ---------------------------------------------------------------------------

def _route(router, cfg, xf):
    """xf (T, D) -> (gates (T,k), expert_ids (T,k), aux scalar)."""
    e, k = cfg.n_experts, cfg.experts_per_token
    logits = xf.astype(jnp.float32) @ router               # (T, E)
    gates, eids = lax.top_k(logits, k)
    gates = jax.nn.softmax(gates, axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    t = xf.shape[0]
    frac = jnp.zeros((e,), jnp.float32).at[eids.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(frac * probs.mean(0))
    return gates, eids, aux


def _dispatch(cfg, xf, eids, capacity):
    """Sort-based dispatch: returns (buf (E, C, D), keep, slot, token_of)."""
    e, k = cfg.n_experts, cfg.experts_per_token
    t, d = xf.shape
    flat_e = eids.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos_in_e, e * capacity)
    token_of = order // k
    buf = jnp.zeros((e * capacity + 1, d), xf.dtype)
    buf = buf.at[slot].set(xf[token_of], mode="drop")
    return buf[: e * capacity].reshape(e, capacity, d), keep, slot, token_of


def _expert_ffn(cfg, buf, wi, wg, wo):
    act = activation(cfg.act)
    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    if wg is not None:
        h = act(jnp.einsum("ecd,edf->ecf", buf, wg)) * h
    else:
        h = act(h)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _capacity(cfg, t: int) -> int:
    return int(max(1, math.ceil(
        cfg.capacity_factor * t * cfg.experts_per_token / cfg.n_experts)))


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantized_all_to_all(x, axis):
    """int8-payload all_to_all (split=concat=0): the wire carries int8 codes
    + one f32 scale per slot (beyond-paper §Perf: the paper's quantization
    theme applied to the EP dispatch). Backward carries full-width
    cotangents (a2a(0,0) is its own transpose)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-9) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    q8 = lax.all_to_all(q.astype(jnp.int8), axis, split_axis=0, concat_axis=0)
    s = lax.all_to_all(scale, axis, split_axis=0, concat_axis=0)
    return (q8.astype(jnp.float32) * s).astype(x.dtype)


def _qa2a_fwd(x, axis):
    return quantized_all_to_all(x, axis), None


def _qa2a_bwd(axis, _, g):
    return (lax.all_to_all(g, axis, split_axis=0, concat_axis=0),)


quantized_all_to_all.defvjp(_qa2a_fwd, _qa2a_bwd)


# ---------------------------------------------------------------------------
# path 1: local reference (rules=None; also the smoke-test oracle)
# ---------------------------------------------------------------------------

def _moe_local(params, cfg, x):
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    gates, eids, aux = _route(params["router"], cfg, xf)
    capacity = _capacity(cfg, t)
    buf, keep, slot, token_of = _dispatch(cfg, xf, eids, capacity)
    out_buf = _expert_ffn(cfg, buf, params["wi"], params.get("wg"),
                          params["wo"])
    out_flat = out_buf.reshape(-1, d)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.minimum(slot, out_flat.shape[0] - 1)],
                         0.0)
    order = jnp.argsort(eids.reshape(-1), stable=True)
    w = gates.reshape(-1)[order][:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[token_of].add(gathered * w)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# paths 2 & 3: expert-parallel on the mesh
# ---------------------------------------------------------------------------

def _dispatch_size(rules) -> int:
    if not rules.expert:
        return 1
    n = 1
    for a in rules.expert:
        n *= int(rules.mesh.shape[a])
    return n


def _ep_mode(cfg, rules) -> str:
    dp = _dispatch_size(rules)
    if cfg.n_experts >= dp and cfg.n_experts % dp == 0 and dp > 1:
        return "alltoall"
    return "gathered"


def _moe_ep(params, cfg, x, rules):
    mesh = rules.mesh
    b, s, d = x.shape
    tp = rules.tp
    seq_sharded = s % tp == 0 and s > 1
    mode = _ep_mode(cfg, rules)
    dp_ax = tuple(rules.expert)  # a2a spans every expert axis (incl. pods)
    dp = _dispatch_size(rules)
    all_axes = tuple(mesh.axis_names)
    glu = is_glu(cfg.act)

    x_in_spec = P(rules.batch, rules.model if seq_sharded else None, None)
    if mode == "alltoall":
        w_spec = {"router": P(), "wi": P(rules.expert, None, rules.model),
                  "wo": P(rules.expert, rules.model, None)}
    else:
        w_spec = {"router": P(), "wi": P(None, None, rules.ff_wide),
                  "wo": P(None, rules.ff_wide, None)}
    if glu:
        w_spec["wg"] = w_spec["wi"]
    if mode == "alltoall" and seq_sharded:
        x_out_spec = P(rules.batch, rules.model, None)
    elif mode == "gathered" and seq_sharded:
        x_out_spec = P(rules.batch, rules.model, None)
    else:
        x_out_spec = P(rules.batch, None, None)

    def body(x_l, p_l):
        if mode == "alltoall" and seq_sharded:
            x_l = lax.all_gather(x_l, rules.model, axis=1, tiled=True)
        bl, sl, _ = x_l.shape
        t = bl * sl
        xf = x_l.reshape(t, d)
        gates, eids, aux = _route(p_l["router"], cfg, xf)
        aux = lax.pmean(aux, all_axes)
        capacity = _capacity(cfg, t)
        buf, keep, slot, token_of = _dispatch(cfg, xf, eids, capacity)

        if mode == "alltoall":
            e_loc = cfg.n_experts // dp
            # layout-preserving exchange: buf rows are expert-major
            # (e = src_dev * e_loc + j), so (dp, e_loc, C, d) is a free view
            # and the expert FFN runs directly on the exchanged layout with
            # j as the batch dim — no 2+ GiB transposes (§Perf iteration).
            send = buf.reshape(dp, e_loc, capacity, d)
            if cfg.moe_a2a_int8:
                recv = quantized_all_to_all(send, dp_ax)
            else:
                recv = lax.all_to_all(send, dp_ax, split_axis=0,
                                      concat_axis=0)
            act = activation(cfg.act)
            h = jnp.einsum("sjcd,jdf->sjcf", recv, p_l["wi"])
            if glu:
                h = act(jnp.einsum("sjcd,jdf->sjcf", recv, p_l["wg"])) * h
            else:
                h = act(h)
            out = jnp.einsum("sjcf,jfd->sjcd", h, p_l["wo"])  # partial/model
            if cfg.moe_a2a_int8 and not seq_sharded:
                # return path can only be quantized when outputs are NOT
                # partial sums over the model axis (quantizing partials
                # before the psum_scatter would compound error) — decode.
                out_buf = quantized_all_to_all(out, dp_ax)
            else:
                out_buf = lax.all_to_all(out, dp_ax, split_axis=0,
                                         concat_axis=0)
            out_buf = out_buf.reshape(cfg.n_experts, capacity, d)
        else:
            wi = lax.all_gather(p_l["wi"], rules.ff_wide, axis=2, tiled=True)
            wo = lax.all_gather(p_l["wo"], rules.ff_wide, axis=1, tiled=True)
            wg = lax.all_gather(p_l["wg"], rules.ff_wide, axis=2,
                                tiled=True) if glu else None
            out_buf = _expert_ffn(cfg, buf, wi, wg, wo)  # complete

        out_flat = out_buf.reshape(-1, d)
        gathered = jnp.where(
            keep[:, None],
            out_flat[jnp.minimum(slot, out_flat.shape[0] - 1)], 0.0)
        order = jnp.argsort(eids.reshape(-1), stable=True)
        w = gates.reshape(-1)[order][:, None].astype(x_l.dtype)
        y = jnp.zeros((t, d), x_l.dtype).at[token_of].add(gathered * w)
        y = y.reshape(bl, sl, d)

        if mode == "alltoall":
            if seq_sharded:   # partial over model -> back to SP in one op
                y = lax.psum_scatter(y, rules.model, scatter_dimension=1,
                                     tiled=True)
            else:
                y = lax.psum(y, rules.model)
        return y, aux

    wrapped = shard_map(
        body, mesh=mesh,
        in_specs=(x_in_spec, w_spec),
        out_specs=(x_out_spec, P()),
        check_rep=False,
    )
    p_used = {k: params[k] for k in w_spec.keys()}
    return wrapped(x, p_used)


def moe_block(params, cfg, x, rules=None):
    """x (B, S, D) -> ((B, S, D), aux_loss)."""
    if rules is not None and getattr(rules, "mesh", None) is not None:
        return _moe_ep(params, cfg, x, rules)
    y, aux = _moe_local(params, cfg, x)
    batch_ax = rules.batch if rules else None
    y = maybe_shard(y, (batch_ax, None, None), rules)
    return y, aux
