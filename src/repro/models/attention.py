"""GQA/MQA attention with RoPE, flash-style chunked prefill, KV-cache decode.

TPU adaptations:
  - prefill never materializes the (S, S) score matrix: online-softmax scan
    over KV chunks (memory O(S * chunk)), MXU-shaped einsums;
  - GQA with TP > n_kv: KV heads are repeated by `kv_repeat` (resolved in
    sharding.rules.head_sharding) so the effective KV head dim shards over
    the model axis — the repeat is a broadcast (no extra projection FLOPs),
    only the cache pays the factor, as in production TP serving;
  - decode attends over the full preallocated cache with a position mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, normal_init
from repro.sharding.rules import maybe_shard

NEG_INF = -1e30


def init_attention(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    return {
        "wq": normal_init(k1, (d, h, hd), std, dtype),
        "wk": normal_init(k2, (d, kv, hd), std, dtype),
        "wv": normal_init(k3, (d, kv, hd), std, dtype),
        "wo": normal_init(k4, (h, hd, d), (h * hd) ** -0.5, dtype),
    }


def _group_query(q, kv_eff):
    """(B, S, H, hd) -> (B, S, KVe, G, hd) with head h -> group h // G."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, kv_eff, h // kv_eff, hd)


def _softcap(scores, cap):
    if cap and cap > 0:
        return jnp.tanh(scores / cap) * cap
    return scores


def _attn_shard_spec(rules, mode):
    heads_ax = rules.model if (rules and mode == "sharded") else None
    batch_ax = rules.batch if rules else None
    return batch_ax, heads_ax


def chunked_prefill_attention(cfg, q, k, v, *, chunk=1024, softcap=0.0,
                              rules=None, mode="replicated"):
    """Causal flash-style attention.

    q (B, S, KVe, G, hd); k, v (B, S, KVe, hd). Returns (B, S, KVe, G, hd).
    Scans KV chunks with a running (max, sum, acc) — never builds (S, S).
    """
    b, s, kve, g, hd = q.shape
    scale = hd ** -0.5
    n_chunks = s // chunk
    kc = k.reshape(b, n_chunks, chunk, kve, hd)
    vc = v.reshape(b, n_chunks, chunk, kve, hd)
    q_pos = jnp.arange(s)

    def body(carry, inputs):
        m, l, acc = carry
        idx, k_blk, v_blk = inputs
        kv_pos = idx * chunk + jnp.arange(chunk)
        # scores: (B, KVe, G, S, chunk)
        sc = jnp.einsum("bskgh,bckh->bkgsc", q.astype(jnp.float32),
                        k_blk.astype(jnp.float32)) * scale
        sc = _softcap(sc, softcap)
        mask = q_pos[:, None] >= kv_pos[None, :]
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        # probs in [0,1]: model-dtype (bf16) for the PV matmul — halves the
        # biggest flash buffer; accumulate in f32 (§Perf iteration)
        pv = jnp.einsum("bkgsc,bckh->bkgsh", p.astype(v_blk.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kve, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kve, g, s), jnp.float32)
    a0 = jnp.zeros((b, kve, g, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(n_chunks), kc.transpose(1, 0, 2, 3, 4),
         vc.transpose(1, 0, 2, 3, 4)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B, S, KVe, G, hd)


def decode_attention(q, k_cache, v_cache, length, *, softcap=0.0):
    """One-token attention over the preallocated cache.

    q (B, 1, KVe, G, hd); caches (B, S_max, KVe, hd); length int32 = #valid.
    """
    s_max = k_cache.shape[1]
    sc = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                    k_cache.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    sc = _softcap(sc, softcap)
    valid = jnp.arange(s_max)[None, None, None, None, :] < length
    sc = jnp.where(valid, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_block(params, cfg, x, positions, *, mode, kv_repeat, rules,
                    cache=None, cache_pos=None, cache_layer=None,
                    prefill_chunk=512):
    """Full attention sub-block.

    Train/prefill: cache=None (returns this block's fresh (k, v)).
    Decode: cache=(k_stack, v_stack) — the FULL (L, B, S_max, KVe, hd)
    stacked caches; the new token is written in place at
    (cache_layer, :, cache_pos) with one tiny dynamic_update_slice (no
    functional per-layer cache copies — see DESIGN.md §5 decode memory).
    Returns (out, new (k, v) stacks).
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    wk, wv = params["wk"], params["wv"]
    if cache is None and kv_repeat > 1:
        # WEIGHT-side KV repeat (§Perf iteration): projecting straight into
        # the tp-shardable kv_eff head space avoids the replicated->head-
        # sharded activation reshard the SPMD partitioner handles with a
        # full rematerialization (repeat of a small weight is free).
        wk = jnp.repeat(wk, kv_repeat, axis=1)
        wv = jnp.repeat(wv, kv_repeat, axis=1)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)

    batch_ax, heads_ax = _attn_shard_spec(rules, mode)
    # replicated-head archs (MQA / odd head counts): shard the *query
    # sequence* over the model axis instead (context-parallel flash) so the
    # (S, chunk) score blocks and the softmax accumulators stay 1/tp-sized;
    # K/V must stay full-sequence for causal attention (they are small).
    seq_ax = None
    if rules is not None and mode == "replicated" and cache is None \
            and s % rules.tp == 0:
        seq_ax = rules.model
    q = maybe_shard(q, (batch_ax, seq_ax, heads_ax, None), rules)

    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        # k/v are already in kv_eff head space (weight-side repeat above);
        # the RETURNED cache keeps TRUE KV heads (decode caches are
        # seq-sharded instead) via a strided head slice.
        kv_eff = cfg.n_kv_heads * kv_repeat
        k_rep = maybe_shard(k, (batch_ax, None, heads_ax, None), rules)
        v_rep = maybe_shard(v, (batch_ax, None, heads_ax, None), rules)
        qg = _group_query(q, kv_eff)
        out = chunked_prefill_attention(
            cfg, qg, k_rep, v_rep, chunk=min(prefill_chunk, s),
            softcap=cfg.attn_softcap, rules=rules, mode=mode)
        new_kv = (k_rep[:, :, ::kv_repeat], v_rep[:, :, ::kv_repeat]) \
            if kv_repeat > 1 else (k_rep, v_rep)
    else:
        # decode: true-KV cache, SEQUENCE-sharded over the model axis
        # (context-parallel decode). GQA handled by query grouping — no
        # repeat, so the cache never pays the kv_repeat factor.
        qg = _group_query(q, max(cfg.n_kv_heads, 1))
        k_stack, v_stack = cache
        layer = cache_layer if cache_layer is not None else 0
        start = (layer, 0, cache_pos, 0, 0)
        k_stack = jax.lax.dynamic_update_slice(k_stack, k[None], start)
        v_stack = jax.lax.dynamic_update_slice(v_stack, v[None], start)
        k_l = jax.lax.dynamic_index_in_dim(k_stack, layer, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(v_stack, layer, 0, keepdims=False)
        out = decode_attention(qg, k_l, v_l, cache_pos + s,
                               softcap=cfg.attn_softcap)
        new_kv = (k_stack, v_stack)

    out = out.reshape(b, s, cfg.n_heads, cfg.head_dim)
    out = maybe_shard(out, (batch_ax, None, heads_ax, None), rules)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_kv
