"""Mamba2 (SSD — state-space duality) layer: chunked train/prefill + O(1)
decode recurrence.

TPU adaptations (DESIGN.md):
  - the SSD block-decomposition runs as a `lax.scan` over sequence chunks
    carrying the running (nh, hd, n) state; the intra-chunk term only
    materializes (B, Q, Q, nh_shard) per step, so HBM stays bounded at 500k
    context and the contractions are MXU einsums. SSD internals in f32.
  - projections are stored as SEPARATE weight blocks (z / x / BC / dt)
    instead of one fused in_proj: the fused layout would be sliced across
    shard boundaries (segments don't align with the 16-way model axis) and
    GSPMD would all-gather the whole activation. Separate blocks keep the
    d_inner/head dims cleanly sharded end-to-end (z, x, dt, conv channels,
    SSD heads), with only the tiny B/C (2*state) replicated.

Single B/C group (n_groups=1), matching mamba2-1.3b / zamba2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import normal_init, norm_like
from repro.sharding.rules import maybe_shard


def conv_channels(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_ssm(key, cfg, dtype):
    d = cfg.d_model
    keys = jax.random.split(key, 6)
    nh, n, di = cfg.ssm_nheads, cfg.ssm_state, cfg.d_inner
    std = d ** -0.5
    return {
        "z_proj": normal_init(keys[0], (d, di), std, dtype),
        "x_proj": normal_init(keys[1], (d, di), std, dtype),
        "bc_proj": normal_init(keys[2], (d, 2 * n), std, dtype),
        "dt_proj": normal_init(keys[3], (d, nh), std, dtype),
        "conv_x_w": normal_init(keys[4], (cfg.ssm_conv, di), 0.2, dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": normal_init(keys[5], (cfg.ssm_conv, 2 * n), 0.2, dtype),
        "conv_bc_b": jnp.zeros((2 * n,), dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),            # A = -exp(a_log) = -1
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": normal_init(keys[3], (di, d), di ** -0.5, dtype),
    }


def _causal_depthwise_conv(x, w, b):
    """x (B, S, C); w (K, C); left-pad K-1 — causal depthwise conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = xp[:, 0:x.shape[1], :] * w[0]
    for i in range(1, k):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out + b


def ssd_chunked(xh, dt, a, b_, c_, d_skip, chunk):
    """SSD forward. xh (B,S,NH,HD) f32; dt (B,S,NH) f32 (post-softplus);
    a (NH,) negative; b_/c_ (B,S,N) f32. Returns y (B,S,NH,HD) f32 and the
    final state (B,NH,HD,N)."""
    bsz, s, nh, hd = xh.shape
    n = b_.shape[-1]
    q = min(chunk, s)
    nc = s // q
    xc = xh.reshape(bsz, nc, q, nh, hd)
    dtc = dt.reshape(bsz, nc, q, nh)
    bc = b_.reshape(bsz, nc, q, n)
    cc = c_.reshape(bsz, nc, q, n)
    tril = jnp.tril(jnp.ones((q, q), bool))

    def step(state, inp):
        xq, dtq, bq, cq = inp                   # (B,q,nh,hd) (B,q,nh) (B,q,n)
        da = dtq * a                            # (B,q,nh)
        cum = jnp.cumsum(da, axis=1)            # (B,q,nh)
        # intra-chunk: y_i += sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
        # the (B,Q,Q,NH) weight block is the SSD memory hot-spot: compute the
        # exp/cumsum in f32 but MATERIALIZE the block in bf16 (values in
        # [0, 1] x gate; the einsum accumulates in f32) — §Perf iteration.
        diff = cum[:, :, None, :] - cum[:, None, :, :]      # (B,i,j,nh)
        decay = jnp.where(tril[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bin,bjn->bij", cq, bq)
        w = (scores[..., None] * decay * dtq[:, None, :, :]).astype(jnp.bfloat16)
        y = jnp.einsum("bijh,bjhp->bihp", w, xq.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        # prior-state contribution: C_i . state decayed from chunk start
        y = y + jnp.einsum("bin,bhpn,bih->bihp", cq, state, jnp.exp(cum))
        # state update: decay full chunk + inject inputs decayed to chunk end
        end_decay = jnp.exp(cum[:, -1, None, :] - cum)      # (B,j,nh)
        state = state * jnp.exp(cum[:, -1, :])[:, :, None, None] \
            + jnp.einsum("bjn,bjh,bjhp->bhpn", bq, dtq * end_decay, xq)
        return state, y

    state0 = jnp.zeros((bsz, nh, hd, n), jnp.float32)
    xs = (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
          bc.transpose(1, 0, 2, 3), cc.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, nh, hd)
    y = y + d_skip[None, None, :, None] * xh
    return y, state


def ssm_block(params, cfg, x, rules=None, cache=None, cache_layer=None,
              chunk=256):
    """Full Mamba2 block. cache None -> train/prefill (returns final state);
    decode: cache = (conv_stack (L,B,K-1,C), state_stack (L,B,NH,HD,N)) with
    in-place per-layer updates at cache_layer (see attention_block note).
    The conv cache packs [x | B | C] channels (x part sharded over model).
    """
    bsz, s, _ = x.shape
    di, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_head_dim
    batch_ax = rules.batch if rules else None
    inner_ax = rules.model if rules else None

    z = jnp.einsum("bsd,de->bse", x, params["z_proj"])
    xin = jnp.einsum("bsd,de->bse", x, params["x_proj"])
    bc = jnp.einsum("bsd,de->bse", x, params["bc_proj"])
    dt = jnp.einsum("bsd,de->bse", x, params["dt_proj"])
    z = maybe_shard(z, (batch_ax, None, inner_ax), rules)
    xin = maybe_shard(xin, (batch_ax, None, inner_ax), rules)
    dt = maybe_shard(dt, (batch_ax, None, inner_ax), rules)

    layer = cache_layer if cache_layer is not None else 0
    if cache is None:
        conv_x = _causal_depthwise_conv(xin, params["conv_x_w"],
                                        params["conv_x_b"])
        conv_bc = _causal_depthwise_conv(bc, params["conv_bc_w"],
                                         params["conv_bc_b"])
        k = cfg.ssm_conv
        tail = jnp.concatenate([xin, bc], axis=-1)[:, -(k - 1):, :]
        new_conv = tail if s >= k - 1 else jnp.pad(
            jnp.concatenate([xin, bc], axis=-1), ((0, 0), (k - 1 - s, 0), (0, 0)))
    else:
        conv_stack, state_stack = cache
        conv_state = jax.lax.dynamic_index_in_dim(conv_stack, layer, 0,
                                                  keepdims=False)
        window_x = jnp.concatenate([conv_state[..., :di], xin], axis=1)
        window_bc = jnp.concatenate([conv_state[..., di:], bc], axis=1)
        conv_x = (jnp.einsum("bkc,kc->bc", window_x, params["conv_x_w"])
                  + params["conv_x_b"])[:, None, :]
        conv_bc = (jnp.einsum("bkc,kc->bc", window_bc, params["conv_bc_w"])
                   + params["conv_bc_b"])[:, None, :]
        new_conv = jnp.concatenate([window_x[:, 1:, :], window_bc[:, 1:, :]],
                                   axis=-1)

    conv_x = jax.nn.silu(conv_x.astype(jnp.float32))
    conv_bc = jax.nn.silu(conv_bc.astype(jnp.float32))
    b_ = conv_bc[..., :n]
    c_ = conv_bc[..., n:]

    xh = conv_x.reshape(bsz, s, nh, hd)
    xh = maybe_shard(xh, (batch_ax, None, inner_ax, None), rules)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])

    if cache is None:
        y, final_state = ssd_chunked(xh, dt, a, b_, c_, params["d_skip"], chunk)
        new_cache = (new_conv, final_state)
    else:
        conv_stack, state_stack = cache
        ssm_state = jax.lax.dynamic_index_in_dim(state_stack, layer, 0,
                                                 keepdims=False)
        da = dt[:, 0, :] * a                                    # (B,nh)
        inject = jnp.einsum("bn,bh,bhp->bhpn", b_[:, 0], dt[:, 0], xh[:, 0])
        ssm_state = ssm_state * jnp.exp(da)[:, :, None, None] + inject
        y = jnp.einsum("bn,bhpn->bhp", c_[:, 0], ssm_state)
        y = y + params["d_skip"][None, :, None] * xh[:, 0]
        y = y[:, None]                                          # (B,1,nh,hd)
        conv_stack = jax.lax.dynamic_update_index_in_dim(
            conv_stack, new_conv[None], layer, 0)
        state_stack = jax.lax.dynamic_update_index_in_dim(
            state_stack, ssm_state[None], layer, 0)
        new_cache = (conv_stack, state_stack)

    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = maybe_shard(y, (batch_ax, None, inner_ax), rules)
    z = jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = norm_like(params, params["norm_w"], y * z, cfg.norm)
    return jnp.einsum("bsi,id->bsd", y, params["out_proj"]), new_cache
