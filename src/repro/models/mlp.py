"""Deprecated alias for `repro.models.lm_mlp` (the transformer feed-forward
blocks). The module was renamed so "mlp" no longer collides with the printed
classifier MLP family (`repro.families.printed_mlp`, DESIGN.md §15)."""
from __future__ import annotations

import warnings

from repro.models.lm_mlp import init_mlp, mlp_block  # noqa: F401

warnings.warn(
    "repro.models.mlp is deprecated: use repro.models.lm_mlp for the "
    "transformer feed-forward blocks (the printed classifier MLP family "
    "lives in repro.families.printed_mlp)",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = ["init_mlp", "mlp_block"]
