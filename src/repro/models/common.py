"""Shared model pieces: norms, RoPE, activations, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normal_init(key, shape, std, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms — computed in f32, cast back to input dtype
# ---------------------------------------------------------------------------

def init_norm(cfg, dtype):
    p = {"w": jnp.zeros((cfg.d_model,), dtype)
         if cfg.norm == "rms1p" else jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layer":
        p["b"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layer":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps)
        w = p["w"].astype(jnp.float32)
        out = out * (1.0 + w) if kind == "rms1p" else out * w
    return out.astype(x.dtype)


def norm_like(p, arbitrary_dim_w, x, kind, eps: float = 1e-6):
    """RMSNorm over an arbitrary trailing dim (SSM gated norm)."""
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    out = xf * jax.lax.rsqrt(ms + eps) * arbitrary_dim_w.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x (..., S, H, hd); positions (..., S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]   # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str):
    if name in ("swiglu", "silu"):
        return jax.nn.silu
    if name in ("geglu", "gelu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def is_glu(name: str) -> bool:
    return name in ("swiglu", "geglu")
