"""LM head: chunked CE loss, KV/SSM cache allocation, model input specs.

The CE loss is computed in sequence chunks under jax.checkpoint so the
(tokens, vocab) logits block is rematerialized per chunk in the backward pass
— at gemma/kimi vocab sizes the full logits tensor would dominate HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.sharding.rules import head_sharding, maybe_shard


def chunked_ce_loss(params, cfg, hidden, targets, mask, rules=None):
    """hidden (B, S, D); targets/mask (B, S). Returns (mean_loss, n_tokens).

    Chunks along the SEQUENCE dim with the batch dim intact, so the
    batch sharding survives the scan (flattening B*S used to defeat GSPMD
    and every device computed every token's logits — §Perf iteration). The
    target log-prob uses an iota-compare-reduce (fusable) instead of a
    gather/one-hot over the vocab-sharded logits.
    """
    b, s, d = hidden.shape
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    cs = min(cfg.loss_chunk, s)
    while s % cs != 0:
        cs //= 2
    cs = max(cs, 1)
    n_chunks = s // cs
    batch_ax = rules.batch if rules else None

    hx = hidden.reshape(b, n_chunks, cs, d).transpose(1, 0, 2, 3)
    tx = targets.reshape(b, n_chunks, cs).transpose(1, 0, 2)
    mx = mask.reshape(b, n_chunks, cs).transpose(1, 0, 2).astype(jnp.float32)
    vocab_iota = jnp.arange(table.shape[0], dtype=jnp.int32)

    def body(carry, inp):
        loss_sum, cnt = carry
        hc, tc, mc = inp                                  # (B, cs, D) ...
        logits = jnp.einsum("bcd,vd->bcv", hc, table).astype(jnp.float32)
        if rules is not None:
            logits = maybe_shard(logits, (batch_ax, None, rules.model), rules)
        logz = jax.nn.logsumexp(logits, axis=-1)          # (B, cs)
        hit = vocab_iota[None, None, :] == tc[:, :, None]
        ll = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
        loss_sum = loss_sum + jnp.sum((logz - ll) * mc)
        return (loss_sum, cnt + mc.sum()), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (loss_sum, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hx, tx, mx))
    return loss_sum / jnp.maximum(cnt, 1.0), cnt


def lm_loss(params, cfg, batch, rules=None, aux_weight=0.01):
    """Causal LM loss. batch: tokens (B, S) [+ prefix_embed (B, P, D)]."""
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embed")
    hidden, _, aux = transformer.forward(
        params, cfg, tokens, rules=rules, prefix_embed=prefix)
    if prefix is not None:
        p = prefix.shape[1]
        hidden = hidden[:, p:, :]          # predict only over text positions
    # next-token prediction: hidden[i] predicts tokens[i+1]
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:]), jnp.zeros_like(tokens[:, :1])], axis=1)
    loss, cnt = chunked_ce_loss(params, cfg, hidden, targets, mask, rules)
    return loss + aux_weight * aux / max(cfg.n_layers, 1), {
        "ce_loss": loss, "aux_loss": aux, "tokens": cnt}


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_caches(cfg, batch_size: int, s_max: int, rules=None, dtype=None):
    """Preallocated decode caches sized for an s_max-token context."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    kv = max(cfg.n_kv_heads, 1)  # TRUE kv heads; decode caches shard on seq
    caches = {}

    def kv_pair(n_stack):
        shape = (n_stack, batch_size, s_max, kv, cfg.head_dim)
        return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    if cfg.family in ("dense", "audio", "vlm", "moe"):
        caches["kv"] = kv_pair(cfg.n_layers)
    elif cfg.family == "ssm":
        caches["ssm"] = _ssm_cache(cfg, cfg.n_layers, batch_size, dtype)
    else:  # hybrid: flat per-layer ssm caches + per-invocation shared kv
        n_super, _, _ = transformer.hybrid_layout(cfg)
        caches["ssm"] = _ssm_cache(cfg, cfg.n_layers, batch_size, dtype)
        caches["shared_kv"] = kv_pair(n_super)
    return caches


def _ssm_cache(cfg, n_stack, batch_size, dtype):
    from repro.models.ssm import conv_channels
    conv = jnp.zeros((n_stack, batch_size, cfg.ssm_conv - 1,
                      conv_channels(cfg)), dtype)
    state = jnp.zeros((n_stack, batch_size, cfg.ssm_nheads,
                       cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    return (conv, state)


def extend_caches(cfg, caches, s_max: int):
    """Convert prefill caches (exact prompt length; hybrid: grouped layout)
    into the decode layout: KV padded out to s_max slots, hybrid SSM caches
    flattened to one (n_layers, ...) stack."""
    def pad_kv(kv):
        k, v = kv
        pad = s_max - k.shape[2]
        widths = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        return (jnp.pad(k, widths), jnp.pad(v, widths))

    out = dict(caches)
    for key in ("kv", "shared_kv"):
        if key in out:
            out[key] = pad_kv(out[key])
    if "ssm_main" in out:  # hybrid prefill layout -> flat decode layout
        main = jax.tree.map(
            lambda a: a.reshape(a.shape[0] * a.shape[1], *a.shape[2:]),
            out.pop("ssm_main"))
        tail = out.pop("ssm_tail", None)
        if tail is not None:
            out["ssm"] = jax.tree.map(
                lambda m, t: jnp.concatenate([m, t], axis=0), main, tail)
        else:
            out["ssm"] = main
    return out


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def prefill(params, cfg, batch, rules=None):
    """Prefill: returns (last-position logits, caches over the prompt)."""
    hidden, caches, _ = transformer.forward(
        params, cfg, batch["tokens"], rules=rules,
        prefix_embed=batch.get("prefix_embed"))
    logits = transformer.logits_from_hidden(params, cfg, hidden[:, -1:, :])
    return logits, caches


def decode_step(params, cfg, token, caches, pos, rules=None):
    """One-token decode against preallocated caches at position `pos`."""
    hidden, new_caches, _ = transformer.forward(
        params, cfg, token, rules=rules, caches=caches, pos0=pos)
    logits = transformer.logits_from_hidden(params, cfg, hidden)
    if rules is not None:
        logits = maybe_shard(logits, (rules.batch, None, rules.model), rules)
    return logits, new_caches
