from repro.models import attention, common, lm, lm_mlp, moe, ssm, transformer

__all__ = ["attention", "common", "lm", "lm_mlp", "moe", "ssm", "transformer"]
