from repro.models import attention, common, lm, mlp, moe, ssm, transformer

__all__ = ["attention", "common", "lm", "mlp", "moe", "ssm", "transformer"]
