"""Dense LM MLP blocks: SwiGLU / GeGLU / GELU / squared-ReLU.

Named `lm_mlp` to keep the transformer feed-forward stack clearly apart
from the printed-classifier MLP family (`repro.families.printed_mlp`,
DESIGN.md §15) — two unrelated things that both used to answer to "mlp".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, is_glu, normal_init
from repro.sharding.rules import maybe_shard


def init_mlp(key, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": normal_init(k1, (d, ff), d ** -0.5, dtype),
        "wo": normal_init(k2, (ff, d), ff ** -0.5, dtype),
    }
    if is_glu(cfg.act):
        p["wg"] = normal_init(k3, (d, ff), d ** -0.5, dtype)
    return p


def mlp_block(params, cfg, x, rules=None):
    act = activation(cfg.act)
    batch_ax = rules.batch if rules else None
    ff_ax = rules.model if rules else None
    h = jnp.einsum("bsd,df->bsf", x, params["wi"])
    if is_glu(cfg.act):
        g = jnp.einsum("bsd,df->bsf", x, params["wg"])
        h = act(g) * h
    else:
        h = act(h)
    h = maybe_shard(h, (batch_ax, None, ff_ax), rules)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"])
