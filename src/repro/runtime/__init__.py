from repro.runtime import checkpoint, serve, train

__all__ = ["checkpoint", "serve", "train"]
