from repro.runtime import checkpoint, classify, lm_serve, train

__all__ = ["checkpoint", "classify", "lm_serve", "serve", "train"]


def __getattr__(name):
    # `serve` is a deprecated alias of `lm_serve` (see runtime/serve.py);
    # importing it lazily keeps the DeprecationWarning out of code that
    # never touches the old name.
    if name == "serve":
        from repro.runtime import serve
        return serve
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
