"""Persistent XLA compilation cache for the search CLIs.

The sweep compiles one program per bucket *shape* (DESIGN.md §11) and the
sharded search one per (mesh, population) layout (§13) — all of them
re-traced identically run after run. Pointing jax's compilation cache at a
persistent directory makes the second run of the same campaign skip straight
to execution; CI keys the directory in the actions cache so the sweep-smoke
job stops recompiling every bucket shape on every push.

Usage (the `--compilation-cache DIR` CLI flag calls this before any jit):

    from repro.runtime import compile_cache
    compile_cache.enable("~/.cache/repro-xla")

Gated: jax builds without `jax.experimental.compilation_cache` (or with an
incompatible API) degrade to a no-op with a warning rather than failing the
run — the cache is a speedup, never a correctness dependency.
"""
from __future__ import annotations

import os
import warnings


def enable(cache_dir: str) -> bool:
    """Route XLA compilations through a persistent on-disk cache.

    Creates ``cache_dir`` if needed and lowers the size/time thresholds so
    the search programs (small by LLM standards, expensive to re-trace per
    bucket shape) actually get cached. Returns True if the cache is active,
    False if this jax build doesn't support it (no-op, warned)."""
    cache_dir = os.path.abspath(os.path.expanduser(cache_dir))
    try:
        from jax.experimental.compilation_cache import compilation_cache as cc
    except ImportError:  # pragma: no cover - depends on the jax build
        warnings.warn("jax.experimental.compilation_cache unavailable; "
                      "--compilation-cache is a no-op on this jax build")
        return False
    os.makedirs(cache_dir, exist_ok=True)
    try:
        import jax
        # cache everything, however small/fast to compile: the sweep's many
        # bucket shapes are individually cheap but collectively dominant
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    except Exception:  # older jax: thresholds don't exist -> defaults apply
        pass
    cc.set_cache_dir(cache_dir)
    return True
