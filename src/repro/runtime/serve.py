"""Serving runtime: batched prefill + decode loops with preallocated caches.

`serve_step` (one decode token against an s_max cache) is what the decode_*
dry-run cells lower; `generate` drives a full prefill + N-token decode for
the examples and tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm


def make_prefill_step(cfg, rules=None):
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, rules=rules)
    return prefill_step


def make_serve_step(cfg, rules=None):
    """One-token decode: (params, token (B,1), caches, pos) -> (logits, caches)."""
    def serve_step(params, token, caches, pos):
        return lm.decode_step(params, cfg, token, caches, pos, rules=rules)
    return serve_step


def generate(params, cfg, prompt_batch, n_tokens: int, s_max: int,
             rules=None, greedy: bool = True, key=None):
    """Prefill the prompt then decode n_tokens autoregressively."""
    logits, caches = lm.prefill(params, cfg, prompt_batch, rules=rules)
    caches = lm.extend_caches(cfg, caches, s_max)
    prompt_len = prompt_batch["tokens"].shape[1] + (
        prompt_batch.get("prefix_embed").shape[1]
        if prompt_batch.get("prefix_embed") is not None else 0)

    serve_step = jax.jit(make_serve_step(cfg, rules))
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None]
    out = [tok]
    for i in range(n_tokens - 1):
        logits, caches = serve_step(params, tok, caches, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
