"""Deprecated alias of `repro.runtime.lm_serve` (the LM decode loop).

`repro.runtime.serve` was ambiguous once the classifier serving runtime
landed (`repro.runtime.classify`, DESIGN.md §14): "serve" here always meant
the LM prefill/decode loop, not serving searched tree designs. Import
`repro.runtime.lm_serve` for the LM path or `repro.runtime.classify` for
the classifier path; this shim keeps old imports working with a
`DeprecationWarning`.
"""
from __future__ import annotations

import warnings

from repro.runtime.lm_serve import (  # noqa: F401
    generate,
    make_prefill_step,
    make_serve_step,
)

warnings.warn(
    "repro.runtime.serve is deprecated: use repro.runtime.lm_serve for the "
    "LM decode loop or repro.runtime.classify for classifier serving",
    DeprecationWarning, stacklevel=2)

__all__ = ["generate", "make_prefill_step", "make_serve_step"]
