"""High-throughput classifier serving runtime (DESIGN.md §14).

The end product of the search is one Pareto design — a printed
decision-tree classifier meant to answer feature-vector queries
continuously. `ClassifyServer` loads that design (from a `pareto.json`
point via `search.load_pareto_artifact`, or directly from decoded
`bits`/`t_int` arrays) and serves it at high request rates:

  - **Request micro-batching on power-of-two buckets.** A request of n
    feature vectors pads up to `sweep.round_up_pow2(n)` — the SAME rounding
    rule as the sweep's shape buckets — so the server compiles one step
    program per bucket, not per request size; padding rows are inert
    (row-independent dataflow: a padded row can never change a real row's
    prediction) and are cropped before results return.
  - **Donated ping-pong device buffers.** Each bucket keeps two resident
    `ServeState` slots used alternately; the step donates the incoming
    slot, so XLA reuses its buffers for the outputs and steady-state
    serving never grows the live-array set. Alternation means the host can
    fill one slot's transfer while the device still computes on the other.
    Donation auto-enables on tpu/gpu only (CPU jax has no donation and
    would warn) — the two-slot structure and the zero-realloc invariant
    hold on every backend.
  - **A featurize → batch → classify stage split** (the classifier analogue
    of an LM server's prefill/insert/generate): `featurize` quantizes float
    features to the master 8-bit grid, `batch` pads request codes to bucket
    shape, and the classify step runs the fused inference kernel.
    `benchmarks/serve_bench.py` times each stage separately and records
    `serving` rows in BENCH_search.json.

Every fast path is pinned bit-exact against the gate-level netlist
simulator (`core/netlist.py`) — the oracle triangle (served == tensor
`predict_votes` == netlist sim) is asserted per pareto point in
`tests/test_serve_classifier.py` and by the CLI's `--verify-netlist`.
Integer inputs are sanitized with a mask (`codes & 0xFF`), NOT a clip:
the netlist reads exactly input bits 0..7, so out-of-grid integers wrap
mod 256 in hardware and the server must (and does) agree bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.tree import concatenate_ptrees
from repro.datasets.synthetic import quantize_u8
from repro.kernels import ops as kops
from repro.search.sweep import GRANULE, round_up_pow2

BACKENDS = ("kernel", "reference")


class ServeState(NamedTuple):
    """One resident serving slot: input buffer, predictions, step count."""

    x: jnp.ndarray      # (bucket, F) int32 master codes
    preds: jnp.ndarray  # (bucket,) int32 predicted classes
    count: jnp.ndarray  # () int32 steps this slot has served


@dataclasses.dataclass
class ServeStats:
    """Serving counters (mutated in place by `ClassifyServer`)."""

    n_requests: int = 0
    n_samples: int = 0
    n_steps: int = 0
    steps_per_bucket: dict = dataclasses.field(default_factory=dict)


def _auto_donate() -> bool:
    # buffer donation is a tpu/gpu feature; CPU jax warns and ignores it
    return jax.default_backend() in ("tpu", "gpu")


class ClassifyServer:
    """Serve one fixed approximate tree/forest design under load.

    Parameters
    ----------
    ptrees : list[ParallelTree]
        The trained ensemble layout (e.g. `ParetoArtifact.ptrees()` or
        `search.problem_ptrees(problem)`).
    bits, t_int : (N,) int arrays
        The decoded design — per-comparator precisions and substituted
        integer thresholds (both PRE-truncation) — concatenated across
        trees in `ptrees` order.
    trunc : (N,) int array | None
        Per-comparator truncated-LSB counts (DESIGN.md §16); None = all
        exact. Folded into effective operands exactly as the search's
        fitness path and the netlist lowering do.
    vote_adder : "exact" (popcount vote adder) or "approx" (saturating
        OR-tree, DESIGN.md §16). Inert for single trees.
    n_classes : int
    n_features : int | None
        Feature-vector width; defaults to the widest feature index any
        comparator reads + 1 (requests may be wider — unused columns are
        ignored, exactly as in the circuit).
    backend : "kernel" (fused Pallas inference, the serving fast path) or
        "reference" (the pure-jnp `predict_votes` dataflow). Both are
        pinned bit-exact to the netlist oracle.
    max_batch : largest bucket; requests beyond it split into chunks.
    granule : smallest bucket (shared with the sweep's `GRANULE`).
    interpret : Pallas interpreter override (None = auto: interpret off-TPU).
    donate : donate the ping-pong slot to the step (None = auto: tpu/gpu).
    """

    def __init__(self, ptrees, bits, t_int, n_classes: int,
                 n_features: int | None = None, *, trunc=None,
                 vote_adder: str = "exact", backend: str = "kernel",
                 max_batch: int = 1024, granule: int = GRANULE,
                 interpret: bool | None = None, donate: bool | None = None):
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown serving backend {backend!r}; options: {BACKENDS}")
        if max_batch < granule:
            raise ValueError(f"max_batch={max_batch} < granule={granule}")
        if vote_adder not in quant.VOTE_ADDER_MODES:
            raise ValueError(
                f"unknown vote_adder {vote_adder!r}; "
                f"options: {quant.VOTE_ADDER_MODES}")
        arrays = concatenate_ptrees(ptrees)
        self.feature = np.asarray(arrays["feature"], np.int32)
        n = self.feature.shape[0]
        bits = np.asarray(bits, np.int32)
        t_int = np.asarray(t_int, np.int32)
        trunc = (np.zeros(n, np.int32) if trunc is None
                 else np.asarray(trunc, np.int32))
        if bits.shape != (n,) or t_int.shape != (n,) or trunc.shape != (n,):
            raise ValueError(
                f"design arrays bits{bits.shape}/t_int{t_int.shape}/"
                f"trunc{trunc.shape} do not match the ensemble's "
                f"{n} comparators")
        if n and (trunc.min() < 0 or trunc.max() > quant.MAX_TRUNC):
            raise ValueError(
                f"trunc values must lie in [0, {quant.MAX_TRUNC}], got "
                f"range [{trunc.min()}, {trunc.max()}]")
        self.bits = bits
        self.t_int = t_int
        self.trunc = trunc
        self.vote_adder = vote_adder
        self.n_classes = int(n_classes)
        self.n_features = int(n_features) if n_features is not None else (
            int(self.feature.max()) + 1 if n else 1)
        if n and self.n_features <= int(self.feature.max()):
            raise ValueError(
                f"n_features={self.n_features} but a comparator reads "
                f"feature {int(self.feature.max())}")
        self.backend = backend
        self.max_batch = int(max_batch)
        self.granule = int(granule)
        self.interpret = interpret
        self.donate = _auto_donate() if donate is None else bool(donate)
        self.stats = ServeStats()

        # design + operands are built ONCE; every bucket's step closes over
        # the same device arrays (the chromosome-invariant prep of §12,
        # specialised to a single fixed design)
        self._design = kops.prepare_design(bits, t_int, trunc=trunc,
                                           vote_adder=vote_adder)
        self._operands = kops.prepare_operands(
            arrays["feature"], arrays["path"], arrays["path_len"],
            arrays["n_neg"], arrays["leaf_class"], self.n_classes,
            self.n_features)
        # reference-backend operands (the predict_votes dataflow) —
        # EFFECTIVE values: truncation folded into precision/threshold,
        # vote cap 1.0 for the approximate adder (DESIGN.md §16)
        self._ref = dict(
            feature=jnp.asarray(self.feature),
            bits=jnp.asarray(bits - trunc),
            t_int=jnp.asarray(t_int >> trunc),
            vote_cap=jnp.float32(
                1.0 if vote_adder == "approx" else np.inf),
            path_t=jnp.asarray(np.asarray(arrays["path"]).T
                               .astype(np.float32)),
            target=jnp.asarray((np.asarray(arrays["path_len"])
                                - np.asarray(arrays["n_neg"]))
                               .astype(np.float32)),
            cls1h=jax.nn.one_hot(jnp.asarray(arrays["leaf_class"]),
                                 self.n_classes),
        )

        self.family = "tree"
        self._steps: dict[int, object] = {}      # bucket -> jitted step
        self._slots: dict[int, list] = {}        # bucket -> [state, state]
        self._slot_idx: dict[int, int] = {}

    # -- construction ------------------------------------------------------

    @classmethod
    def for_mlp(cls, w1, w2, shift: int, n_classes: int,
                n_features: int | None = None, *, backend: str = "kernel",
                max_batch: int = 1024, granule: int = GRANULE,
                interpret: bool | None = None,
                donate: bool | None = None) -> "ClassifyServer":
        """Serve a printed-MLP design (effective integer weights).

        Same bucketed ping-pong machinery as the tree server — only `_infer`
        differs: `kernel` routes the first layer through `kernels.qmatmul`
        (int8 weights), `reference` is the pure-jnp matmul; both are
        integer-exact in f32 and pinned to `core.netlist.build_mlp_circuit`.
        """
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown serving backend {backend!r}; options: {BACKENDS}")
        if max_batch < granule:
            raise ValueError(f"max_batch={max_batch} < granule={granule}")
        w1 = np.asarray(w1, np.int32)
        w2 = np.asarray(w2, np.int32)
        if w1.ndim != 2 or w2.ndim != 2 or w2.shape[0] != w1.shape[1]:
            raise ValueError(
                f"weight shapes w1{w1.shape}/w2{w2.shape} do not chain "
                f"(expected (F, H) @ (H, C))")
        if w2.shape[1] != n_classes:
            raise ValueError(
                f"w2 has {w2.shape[1]} output columns for "
                f"n_classes={n_classes}")
        self = cls.__new__(cls)
        self.family = "mlp"
        self.w1 = w1
        self.w2 = w2
        self.shift = int(shift)
        self.n_classes = int(n_classes)
        self.n_features = (int(n_features) if n_features is not None
                           else int(w1.shape[0]))
        if self.n_features != w1.shape[0]:
            raise ValueError(
                f"n_features={self.n_features} but w1 reads {w1.shape[0]} "
                f"features")
        self.backend = backend
        self.max_batch = int(max_batch)
        self.granule = int(granule)
        self.interpret = interpret
        self.donate = _auto_donate() if donate is None else bool(donate)
        self.stats = ServeStats()
        self._mlp = dict(
            w1_i8=jnp.asarray(w1, jnp.int8),
            w1_f=jnp.asarray(w1, jnp.float32),
            w2_f=jnp.asarray(w2, jnp.float32),
            ones=jnp.ones((w1.shape[1],), jnp.float32),
            shift_scale=jnp.float32(2.0 ** -self.shift),
        )
        self._steps = {}
        self._slots = {}
        self._slot_idx = {}
        return self

    @classmethod
    def from_artifact(cls, artifact, point: int | str = "best",
                      max_loss: float = 0.01, **opts) -> "ClassifyServer":
        """Serve a `pareto.json` point.

        ``artifact`` is a loaded artifact of ANY family (tree
        `search.ParetoArtifact` or MLP `families.printed_mlp.
        MlpParetoArtifact`) or a path to pareto.json; ``point`` selects the
        pareto index, or "best" for the smallest-area point within
        ``max_loss``. The design re-materializes from the artifact alone
        (DESIGN.md §14/§15).
        """
        from repro.search import artifact as _artifact

        if isinstance(artifact, str):
            artifact = _artifact.load_pareto_artifact(artifact)
        if point == "best":
            idx = artifact.best_under_loss(max_loss)
            if idx is None:
                raise ValueError(
                    f"no pareto point within max_loss={max_loss}; "
                    f"losses: {[p['acc_loss'] for p in artifact.points]}")
        else:
            idx = int(point)
            if not 0 <= idx < len(artifact.points):
                raise ValueError(
                    f"pareto point {idx} out of range "
                    f"(artifact has {len(artifact.points)} points)")
        if getattr(artifact, "family", "tree") == "mlp":
            w1, w2 = artifact.point_design(idx)
            server = cls.for_mlp(w1, w2, artifact.shift, artifact.n_classes,
                                 artifact.n_features, **opts)
        else:
            bits, t_int, trunc, vote_adder = artifact.point_design(idx)
            server = cls(artifact.ptrees(), bits, t_int, artifact.n_classes,
                         trunc=trunc, vote_adder=vote_adder, **opts)
        server.artifact = artifact
        server.point_index = idx
        return server

    # -- the three serving stages -----------------------------------------

    def featurize(self, x) -> np.ndarray:
        """Float features in [0, 1] (n, F) -> master 8-bit codes (n, F)."""
        return quantize_u8(np.asarray(x))

    def sanitize(self, codes) -> np.ndarray:
        """Integer codes -> the 8 input bits the circuit actually reads.

        A MASK, not a clip: `core.netlist.simulate` reads bits 0..7 of each
        input, so any integer wraps mod 256 in hardware — serving must
        reproduce that bit-for-bit for out-of-grid values too.
        """
        return (np.asarray(codes).astype(np.int64) & 0xFF).astype(np.int32)

    def bucket_for(self, n: int) -> int:
        """Power-of-two batch bucket serving a request of n rows."""
        return min(self.max_batch, round_up_pow2(n, self.granule))

    def batch(self, codes) -> list[tuple[np.ndarray, int]]:
        """Pad request codes up to bucket shape(s).

        Returns [(padded (bucket, F) int32, n_real)], one entry per
        `max_batch` chunk (a single entry for requests that fit one
        bucket). Padding rows are zero — inert by row independence.
        """
        codes = np.asarray(codes, np.int32)
        if codes.ndim != 2:
            raise ValueError(f"expected (n, F) codes, got shape {codes.shape}")
        if codes.shape[1] < self.n_features:
            raise ValueError(
                f"request has {codes.shape[1]} features; the design reads "
                f"{self.n_features}")
        out = []
        for lo in range(0, codes.shape[0], self.max_batch) or [0]:
            chunk = codes[lo:lo + self.max_batch]
            bucket = self.bucket_for(chunk.shape[0])
            padded = np.zeros((bucket, codes.shape[1]), np.int32)
            padded[:chunk.shape[0]] = chunk
            out.append((padded, chunk.shape[0]))
        return out

    def classify_codes(self, codes) -> np.ndarray:
        """(n, F) integer master codes -> (n,) predicted classes."""
        codes = self.sanitize(codes)
        self.stats.n_requests += 1
        self.stats.n_samples += int(codes.shape[0])
        if codes.shape[0] == 0:
            return np.zeros((0,), np.int32)
        preds = [np.asarray(self.step(padded))[:n]
                 for padded, n in self.batch(codes)]
        return np.concatenate(preds).astype(np.int32)

    def classify(self, x) -> np.ndarray:
        """Serve one request: (n, F) features -> (n,) predicted classes.

        Float inputs are featurized to the master grid; integer inputs are
        taken as codes (masked to the circuit's 8 input bits). Non-finite
        float features (NaN/±inf) are rejected with a `ValueError` before
        the float->int quantization cast — `np.floor(nan).astype(int)` is
        undefined behavior, and a printed sensor frontend feeding NaN is a
        fault the caller must see, not a silently-served garbage class.
        """
        x = np.asarray(x)
        if np.issubdtype(x.dtype, np.integer):
            return self.classify_codes(x)
        bad = ~np.isfinite(x)
        if bad.any():
            rows = np.unique(np.nonzero(bad)[0])[:8]
            raise ValueError(
                f"classify: non-finite feature values (NaN/inf) in "
                f"{int(bad.sum())} entries (rows {rows.tolist()}...); "
                f"features must be finite floats in [0, 1]")
        return self.classify_codes(self.featurize(x))

    # -- bucketed ping-pong step ------------------------------------------

    def step(self, padded: np.ndarray):
        """Run one bucket-shaped batch through the resident step.

        `padded` is (bucket, F) int32 from `batch`. Returns the device
        predictions array (bucket,) — callers crop to the real row count.
        """
        bucket = int(padded.shape[0])
        step_fn = self._steps.get(bucket)
        if step_fn is None:
            step_fn = self._steps[bucket] = self._build_step(bucket)
            self._slots[bucket] = [None, None]
            self._slot_idx[bucket] = 0
        idx = self._slot_idx[bucket]
        state = self._slots[bucket][idx]
        if state is None:  # warmup: allocate this slot's resident buffers
            state = ServeState(
                x=jnp.zeros(padded.shape, jnp.int32),
                preds=jnp.zeros((bucket,), jnp.int32),
                count=jnp.int32(0))
        state = step_fn(state, jnp.asarray(padded))
        self._slots[bucket][idx] = state
        self._slot_idx[bucket] = idx ^ 1  # ping-pong
        self.stats.n_steps += 1
        self.stats.steps_per_bucket[bucket] = (
            self.stats.steps_per_bucket.get(bucket, 0) + 1)
        return state.preds

    def _infer(self, x8):
        """(bucket, F) codes -> (bucket,) predictions, selected backend."""
        if self.family == "mlp":
            m = self._mlp
            xf = x8[:, :self.n_features].astype(jnp.float32)
            if self.backend == "kernel":
                h = kops.qmatmul(xf, m["w1_i8"], m["ones"],
                                 interpret=self.interpret)
            else:
                h = xf @ m["w1_f"]
            hq = jnp.floor(jnp.maximum(h, 0.0) * m["shift_scale"])
            return jnp.argmax(hq @ m["w2_f"], axis=1).astype(jnp.int32)
        if self.backend == "kernel":
            bucket = x8.shape[0]
            return kops.classify(
                x8, self._operands, self._design,
                block_b=min(256, bucket),
                interpret=self.interpret).astype(jnp.int32)
        r = self._ref
        x_p = quant.inputs_at_precision(x8[:, r["feature"]], r["bits"])
        t_sub = r["t_int"][None, :]
        d = (x_p > t_sub).astype(jnp.float32)
        score = d @ r["path_t"]
        sat = (score == r["target"][None, :]).astype(jnp.float32)
        votes = sat @ r["cls1h"]
        # saturating (approximate) vote adder: +inf cap = exact f32 no-op
        votes = jnp.minimum(votes, r["vote_cap"])
        return jnp.argmax(votes, axis=1).astype(jnp.int32)

    def _build_step(self, bucket: int):
        def step(state: ServeState, x_new) -> ServeState:
            return ServeState(x=x_new, preds=self._infer(x_new),
                              count=state.count + 1)

        donate = (0,) if self.donate else ()
        return jax.jit(step, donate_argnums=donate)

    # -- accounting --------------------------------------------------------

    def compiled_buckets(self) -> list[int]:
        return sorted(self._steps)

    def compile_count(self) -> int:
        """Total compiled step specializations across buckets — steady-state
        serving must not grow this (`serve_bench` records the delta as
        `compiles_after_warmup`, floor-checked at 0)."""
        return sum(fn._cache_size() for fn in self._steps.values())
