"""Train step factory: loss + grads + optimizer update, with gradient
accumulation (microbatch scan — lets XLA overlap per-microbatch reduce-
scatter with the next microbatch's compute) and global-norm clipping."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim import clip_by_global_norm, get_optimizer


@dataclasses.dataclass
class TrainState:
    params: dict
    opt_state: dict
    step: jnp.ndarray


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state, s.step), None),
    lambda _, c: TrainState(*c),
)


def init_train_state(params, optimizer) -> TrainState:
    return TrainState(params, optimizer.init(params), jnp.int32(0))


def make_train_step(cfg, rules=None, optimizer=None, max_grad_norm: float = 1.0):
    optimizer = optimizer or get_optimizer(cfg)

    def loss_fn(params, batch):
        loss, metrics = lm.lm_loss(params, cfg, batch, rules=rules)
        return loss, metrics

    def compute_grads(params, batch):
        if cfg.grad_accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        n = cfg.grad_accum
        acc_dtype = jnp.dtype(cfg.grad_accum_dtype)
        micro = jax.tree.map(
            lambda a: a.reshape(n, a.shape[0] // n, *a.shape[1:]), batch)

        def body(carry, mb):
            loss_sum, grads_sum = carry
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            grads_sum = jax.tree.map(
                lambda a, g: a + g.astype(acc_dtype), grads_sum, grads)
            return (loss_sum + loss, grads_sum), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params)
        (loss_sum, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros),
                                            micro)
        inv = 1.0 / n
        grads = jax.tree.map(lambda g: g * inv, grads)
        return loss_sum * inv, {"ce_loss": loss_sum * inv}, grads

    def train_step(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params, state.step)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
