"""LM serving runtime: batched prefill + decode loops with preallocated caches.

`serve_step` (one decode token against an s_max cache) is what the decode_*
dry-run cells lower; `generate` drives a full prefill + N-token decode for
the examples and tests.

Formerly `repro.runtime.serve` — renamed so the LM decode loop cannot be
confused with the classifier serving runtime (`repro.runtime.classify`,
DESIGN.md §14). The old module name remains as a deprecation shim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm


def make_prefill_step(cfg, rules=None):
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, rules=rules)
    return prefill_step


def make_serve_step(cfg, rules=None):
    """One-token decode: (params, token (B,1), caches, pos) -> (logits, caches)."""
    def serve_step(params, token, caches, pos):
        return lm.decode_step(params, cfg, token, caches, pos, rules=rules)
    return serve_step


def generate(params, cfg, prompt_batch, n_tokens: int, s_max: int,
             rules=None, greedy: bool = True, key=None,
             temperature: float = 1.0):
    """Prefill the prompt then decode exactly `n_tokens` autoregressively.

    greedy=True: argmax decoding (`key` ignored). greedy=False: temperature
    sampling via `jax.random.categorical` — `key` is required and is split
    once per generated token, so the same key reproduces the same sequence.
    Returns (B, n_tokens) int32; `n_tokens=0` returns an empty (B, 0) array.
    """
    if n_tokens <= 0:
        return jnp.zeros((prompt_batch["tokens"].shape[0], 0), jnp.int32)
    if not greedy and key is None:
        raise ValueError("greedy=False sampling requires a PRNG `key`")

    def pick(logits, k):
        lg = logits[:, -1, :cfg.vocab_size]
        if greedy:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
        lg = lg.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
        return jax.random.categorical(k, lg, axis=-1).astype(jnp.int32)[:, None]

    keys = (jax.random.split(key, n_tokens) if not greedy
            else [None] * n_tokens)
    logits, caches = lm.prefill(params, cfg, prompt_batch, rules=rules)
    caches = lm.extend_caches(cfg, caches, s_max)
    prompt_len = prompt_batch["tokens"].shape[1] + (
        prompt_batch.get("prefix_embed").shape[1]
        if prompt_batch.get("prefix_embed") is not None else 0)

    serve_step = jax.jit(make_serve_step(cfg, rules))
    tok = pick(logits, keys[0])
    out = [tok]
    for i in range(n_tokens - 1):
        logits, caches = serve_step(params, tok, caches, jnp.int32(prompt_len + i))
        tok = pick(logits, keys[i + 1])
        out.append(tok)
    return jnp.concatenate(out, axis=1)
