"""Sharded checkpointing with elastic restore (DESIGN.md §6).

Format: one .npz per save holding every leaf (flattened tree paths as keys)
+ a JSON manifest (step, tree structure, shapes, dtypes). Restore accepts a
*different* mesh / device count: arrays are device_put with the new sharding
(elastic scaling after node loss). Writes are atomic (tmp + rename) and the
last K checkpoints are retained, so a crash mid-write never corrupts the
restore point — the checkpoint/restart fault-tolerance contract.

On a real multi-host pod each host writes only its addressable shards; here
the single-process container writes the full array (the format keeps a
`shards` field so the multi-host writer slots in without format changes).
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(ckpt_dir: str, step: int, tree, keep: int = 3,
         meta: dict | None = None) -> str:
    """`meta`: optional JSON-serializable producer metadata stored in the
    manifest (e.g. the search engine records its backend family so a resume
    with an incompatible state layout fails with a clear error instead of a
    shape assertion — see repro.search.engine)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, _ = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in leaves.items()}
    manifest = {
        "step": int(step),
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "shards": "full",
        "meta": meta or {},
    }
    final = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    with tempfile.TemporaryDirectory(dir=ckpt_dir) as tmp:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.makedirs(final + ".tmp", exist_ok=True)
        for name in ("arrays.npz", "manifest.json"):
            os.replace(os.path.join(tmp, name), os.path.join(final + ".tmp", name))
    os.replace(final + ".tmp", final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """The JSON manifest of one checkpoint (includes the `meta` dict)."""
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f)


def checkpoint_error(ckpt_dir: str, step: int) -> str | None:
    """Why `ckpt_<step>` cannot be restored, or None if it looks intact.

    Probes everything `restore` depends on without touching devices: the
    manifest must parse and carry its required fields, `arrays.npz` must
    open AND fully decompress (a truncated write fails on read, not on
    open), and every manifest key must be present in the archive.
    """
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        missing = [k for k in ("step", "keys", "shapes", "dtypes")
                   if k not in manifest]
        if missing:
            return f"manifest.json missing fields {missing}"
        with np.load(os.path.join(path, "arrays.npz")) as data:
            for key in manifest["keys"]:
                arr = data[key]   # forces decompression of the member
                if list(arr.shape) != list(manifest["shapes"][key]):
                    return (f"arrays.npz[{key!r}] shape {list(arr.shape)} "
                            f"!= manifest {manifest['shapes'][key]}")
    except Exception as e:  # corrupt JSON, truncated zip, missing member...
        return f"{type(e).__name__}: {e}"
    return None


def latest_step(ckpt_dir: str) -> int | None:
    """Newest *intact* checkpoint step, or None.

    A crash can leave a partially-written or corrupted `ckpt_<step>/`
    (e.g. a torn filesystem under the atomic-rename contract, or manual
    tampering); rather than letting the subsequent `restore` crash the
    resume, each candidate is verified newest-first with
    `checkpoint_error` and broken ones are skipped with a warning.
    """
    import warnings

    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted((int(m.group(1)) for d in os.listdir(ckpt_dir)
                    if (m := re.fullmatch(r"ckpt_(\d+)", d))), reverse=True)
    for step in steps:
        err = checkpoint_error(ckpt_dir, step)
        if err is None:
            return step
        warnings.warn(f"skipping unreadable checkpoint "
                      f"{ckpt_dir}/ckpt_{step:08d}: {err}")
    return None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree`.

    shardings: optional matching pytree of jax.sharding.Sharding — arrays are
    device_put with them (elastic restore onto a new mesh).
    """
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten_with_paths(like_tree)
    shard_leaves = None
    if shardings is not None:
        shard_leaves, _ = _flatten_with_paths(shardings)

    restored = {}
    for key, like in leaves.items():
        arr = data[key]
        assert list(arr.shape) == list(like.shape), (key, arr.shape, like.shape)
        target = jnp.asarray(arr, dtype=like.dtype)
        if shard_leaves is not None:
            target = jax.device_put(target, shard_leaves[key])
        restored[key] = target
    ordered = [restored[k] for k in leaves.keys()]
    return jax.tree_util.tree_unflatten(treedef, ordered), manifest["step"]


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        int(m.group(1)) for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"ckpt_(\d+)", d)))
    for s in steps[:-keep]:
        import shutil
        shutil.rmtree(os.path.join(ckpt_dir, f"ckpt_{s:08d}"), ignore_errors=True)
