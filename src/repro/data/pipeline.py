"""Token data pipeline: deterministic synthetic corpus, sharded batches,
double-buffered prefetch, straggler-tolerant skip.

The corpus is a Zipf-ish Markov stream (stable unigram/bigram statistics so
training losses are meaningfully decreasing, unlike uniform noise). Batches
are indexed by (step, shard): any host can regenerate any shard's batch from
the seed alone — which is what makes the redundant "hot spare" data shards
and checkpoint-restart cheap (no data-state to restore beyond the step).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # sparse bigram transition: each token has few likely successors
        self._succ = rng.integers(0, v, size=(v, 4))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_a)
        self._unigram = p / p.sum()

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """Deterministic batch for (step, shard). tokens (B/n_shards, S)."""
        b = self.global_batch // n_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard)
        s = self.seq_len
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.choice(self.vocab_size, size=b, p=self._unigram)
        follow = rng.random((b, s)) < 0.8
        pick = rng.integers(0, 4, size=(b, s))
        fresh = rng.choice(self.vocab_size, size=(b, s), p=self._unigram)
        for t in range(1, s):
            nxt = self._succ[toks[:, t - 1], pick[:, t]]
            toks[:, t] = np.where(follow[:, t], nxt, fresh[:, t])
        return {"tokens": toks}


def make_batch_specs(cfg, shape_cfg, prefix_dtype="float32"):
    """jax.ShapeDtypeStruct stand-ins for every model input of a shape cell
    (the dry-run pattern: weak-type-correct, shardable, no allocation)."""
    import jax.numpy as jnp
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    text = s - cfg.prefix_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, text), jnp.int32)}
    if cfg.prefix_len:
        specs["prefix_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.prefix_len, cfg.d_model), jnp.dtype(prefix_dtype))
    return specs
