"""The `ClassifierFamily` protocol (DESIGN.md §15).

A *family* is one kind of printed classifier the NSGA-II engine can search:
bespoke decision trees/forests (the source paper) or integer-weight printed
MLPs (the sibling work, arxiv 2402.02930 / 2312.17612). The engine layers —
`search.engine`, `search.backends`, `search.sweep`, the artifact schema,
`runtime.classify` and both CLIs — speak only this protocol; everything
tree-specific lives behind `families/tree.py` and everything MLP-specific
behind `families/printed_mlp.py`.

A family owns five concerns:

  1. **Problem construction + genes** — `build_problem` binds a dataset to a
     family-specific problem object; `n_genes`/`exact_genes` define the
     real-coded [0, 1] chromosome and the exact (lossless) seed design.
     The NSGA-II operators are gene-position-agnostic, so a family may
     enlarge its gene space freely — the tree family's cross-layer
     approximation layout (per-comparator precision/margin/truncation plus
     a forest-level vote-adder gene) is DESIGN.md §16.
  2. **Fitness** — `make_fitness(problem, backend)` returns the population
     fitness `(P, n_genes) -> (P, 2)` for the `reference` (pure jnp) and
     `kernel` (fused Pallas route) backends. Both must agree bit-exactly:
     every reduction is integer-valued in f32 (DESIGN.md §11/§12).
  3. **Sweep padding** — `problem_dims`/`pad_problem`/`population_objectives`
     lower the problem onto bucket-boundary shapes with *inert* padding so
     the multi-dataset campaign can stack and vmap problems of one family
     (`search.sweep` keys its buckets by `(family, dims)`).
  4. **Hardware lowering** — `write_artifact` emits the validated
     family-tagged `pareto.json` (plus per-point Verilog under `--emit-rtl`)
     and, under `--verify-rtl`, asserts the oracle triangle per pareto
     point: netlist sim == tensor predict == kernel backend.
  5. **Serving** — `load_artifact` re-materializes a design from the JSON
     alone and `make_server` stands up the bucketed
     `runtime.classify.ClassifyServer` for it.

Methods raise `NotImplementedError` here; concrete families override all of
them. `repro.families.get_family` / `family_of` / `family_of_payload` are
the registry lookups the engine layers use.
"""
from __future__ import annotations


class ClassifierFamily:
    """Abstract base for one searchable printed-classifier family."""

    #: registry key ("tree", "mlp", ...) — also the artifact's `family` tag
    name: str = "?"

    # -- problem construction + genes -------------------------------------

    def owns(self, problem) -> bool:
        """True if `problem` is this family's problem type."""
        raise NotImplementedError

    def build_problem(self, dataset: str, **opts):
        """Train the exact design on `dataset` and bind its test split."""
        raise NotImplementedError

    def n_genes(self, problem) -> int:
        """Chromosome length for `problem` (trees: 3N+1, DESIGN.md §16)."""
        raise NotImplementedError

    def exact_genes(self, problem):
        """(n_genes,) chromosome decoding to the exact (lossless) design —
        for families with approximation genes (DESIGN.md §16) that means
        every approximate cell switched OFF, so the seed prices and scores
        identically to the pre-approximation exact design."""
        raise NotImplementedError

    def describe(self, problem) -> str:
        """One-line problem summary for CLI headers."""
        raise NotImplementedError

    # -- fitness -----------------------------------------------------------

    def make_fitness(self, problem, backend: str = "reference", **kw):
        """Population fitness `(P, n_genes) -> (P, 2)` on `backend`."""
        raise NotImplementedError

    # -- sweep padding (DESIGN.md §11) -------------------------------------

    def problem_dims(self, problem) -> tuple:
        """Real (unpadded) operand extents — the bucket shape key."""
        raise NotImplementedError

    def pad_problem(self, problem, dims: tuple):
        """Pad to bucket dims with inert padding; returns a stackable pytree."""
        raise NotImplementedError

    def population_objectives(self, padded, pop):
        """(P, padded n_genes) -> (P, 2) on a padded (or stacked) context."""
        raise NotImplementedError

    def padded_n_genes(self, dims: tuple) -> int:
        """Chromosome length at padded bucket dims (DESIGN.md §11)."""
        raise NotImplementedError

    def padded_exact_genes(self, dims: tuple):
        """Exact-design seed chromosome at padded dims (inert pad genes)."""
        raise NotImplementedError

    def unpad_genes(self, problem, genes, dims: tuple):
        """Map a padded population's gene columns back to `problem`'s real
        layout. Not necessarily a prefix slice: layouts with trailing
        design-level genes (DESIGN.md §16) must relocate them."""
        raise NotImplementedError

    def eval_cost(self, dims: tuple) -> float:
        """Dominant per-chromosome FLOP count at padded dims (bucket merge)."""
        raise NotImplementedError

    # -- artifacts + serving (DESIGN.md §10/§14) ---------------------------

    def write_artifact(self, problem, result, out_dir: str, *,
                      emit_rtl: bool = False, verify_rtl: bool = False,
                      dataset: str | None = None) -> str:
        """Write the family-tagged pareto.json (+ RTL / oracle triangle)."""
        raise NotImplementedError

    def load_artifact(self, payload_or_path):
        """Validate + materialize this family's artifact object."""
        raise NotImplementedError

    def make_server(self, artifact, point="best", max_loss: float = 0.01,
                    **opts):
        """Stand up a `runtime.classify.ClassifyServer` for a pareto point."""
        raise NotImplementedError

    def build_point_circuit(self, artifact, idx: int):
        """Gate-level netlist of pareto point `idx` (the serving oracle)."""
        raise NotImplementedError
