"""Classifier family registry (DESIGN.md §15).

`FAMILIES` maps the registry key ("tree", "mlp") to the singleton family
object; the engine layers resolve families through the three lookups below
instead of importing family modules directly.
"""
from __future__ import annotations

from repro.families import printed_mlp, tree
from repro.families.base import ClassifierFamily

FAMILIES: dict[str, ClassifierFamily] = {
    tree.FAMILY.name: tree.FAMILY,
    printed_mlp.FAMILY.name: printed_mlp.FAMILY,
}


def get_family(name: str) -> ClassifierFamily:
    """Registry-key lookup ("tree" / "mlp")."""
    try:
        return FAMILIES[name]
    except KeyError:
        raise ValueError(f"unknown classifier family {name!r}; "
                         f"known: {sorted(FAMILIES)}") from None


def family_of(problem) -> ClassifierFamily:
    """The family owning a problem object (by `owns` probe)."""
    for fam in FAMILIES.values():
        if fam.owns(problem):
            return fam
    raise TypeError(f"no registered classifier family owns "
                    f"{type(problem).__name__}")


def family_of_payload(payload: dict) -> ClassifierFamily:
    """The family of a pareto.json payload (absent tag -> legacy tree)."""
    return get_family(payload.get("family", "tree"))


__all__ = ["ClassifierFamily", "FAMILIES", "get_family", "family_of",
           "family_of_payload", "tree", "printed_mlp"]
