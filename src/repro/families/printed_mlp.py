"""The printed-MLP family: integer-weight MAC genes on the shared engine.

Implements `ClassifierFamily` (DESIGN.md §15) for one-hidden-layer printed
MLPs in the style of the sibling printed-electronics work (hardware-aware
genetic search over discrete MLP weights, arxiv 2402.02930; bespoke
approximate MAC/activation circuits, arxiv 2312.17612), re-using this repo's
dual-approximation recipe end to end:

  - **Master weights.** A small float MLP (no biases, ReLU hidden layer) is
    trained deterministically per dataset, then each layer is quantized with
    a single per-layer scale to 4-bit signed *master codes* in [-8, 7].
    With no biases the network is positively homogeneous, so per-layer
    scales never change the argmax — the hardware drops them entirely and
    computes pure integer arithmetic on the 8-bit input codes.
  - **Genes.** Two genes per *neuron* (hidden and output), exactly the
    comparator chromosome layout: a precision gene (weight bits in
    [2, 4] — truncation of the master code, mirroring `core.quant`'s
    right-shift ladder) and a margin gene (snap window in [0, 5]). Margins
    snap each truncated code to the cheapest popcount pattern within the
    window through `quantize.bespoke.snap_lut` — the paper's
    move-threshold-to-cheap-bit-pattern generalized from comparator
    thresholds to MAC multiplier constants (the snap is iterated to a
    fixpoint there, so re-snapping through the precision ladder is stable).
  - **Decode tables.** There are only 3 x 6 = 18 (bits, margin) combos, so
    decode is a gather: `TW1[combo, F, H]` / `TW2[combo, H, C]` hold every
    neuron's *effective* integer weights per combo (truncate -> snap ->
    rescale to the master grid) and `COST1`/`COST2` their area in integer
    `AREA_QUANTUM_MM2` quanta (`core.area.mlp_neuron_area_units`: shifted-
    copy full-adder MAC rows + one activation cell). Integer-quanta area
    sums and integer-valued f32 accuracy sums make the fitness bit-exact
    under any vmap tiling — the same exactness contract as the tree family
    (DESIGN.md §11).
  - **Exact forward in f32.** `x8f @ W1` sums products bounded by
    255 * 8 * F < 2^24, the ReLU output is floor-shifted by a static
    per-problem `shift` (exact: multiply by a power of two, then floor) so
    the second layer's sums stay < 2^24 too. The fused-kernel fitness
    routes the population's first layer through ONE `kernels.ops.qmatmul`
    launch (weights concatenated on the output axis) and is bit-identical
    to the reference path.
  - **Oracle triangle.** `--verify-rtl` asserts, per pareto point,
    netlist sim (`core.netlist.build_mlp_circuit`) == integer tensor
    predict == kernel route, exactly as the tree family does.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import area as area_mod
from repro.core import netlist
from repro.families.base import ClassifierFamily
from repro.quantize import bespoke

MASTER_WBITS = 4            # master weight codes are 4-bit signed: [-8, 7]
WB_MIN, WB_MAX = 2, 4       # precision gene range (truncations of the master)
N_MARGINS = 6               # margin gene range [0, 5], as for comparators
N_COMBOS = (WB_MAX - WB_MIN + 1) * N_MARGINS        # 18 decode table rows
EXACT_COMBO = (WB_MAX - WB_MIN) * N_MARGINS         # (bits=4, margin=0)
DEFAULT_HIDDEN = 16
# f32-exact "minus infinity" for masking padded classes out of the argmax:
# real scores are integers with |s| < 2^24, so -2^25 can never win
_NEG_SENTINEL = -float(1 << 25)


# ---------------------------------------------------------------------------
# training + master quantization
# ---------------------------------------------------------------------------

def train_mlp(x_train, y_train, n_classes: int, n_hidden: int = DEFAULT_HIDDEN,
              n_steps: int = 300, lr: float = 0.5, seed: int = 0):
    """Deterministic full-batch GD on a bias-free one-hidden-layer ReLU MLP.

    Returns float (w1 (F, H), w2 (H, C)). Bias-free keeps the network
    positively homogeneous, which is what lets the integer pipeline drop
    the quantization scales without moving the argmax.
    """
    x = jnp.asarray(x_train, jnp.float32)
    y = jnp.asarray(y_train, jnp.int32)
    n_features = x.shape[1]
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w1 = jax.random.normal(k1, (n_features, n_hidden),
                           jnp.float32) * n_features ** -0.5
    w2 = jax.random.normal(k2, (n_hidden, n_classes),
                           jnp.float32) * n_hidden ** -0.5

    def loss_fn(params):
        h = jax.nn.relu(x @ params[0])
        logits = h @ params[1]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(logp[jnp.arange(x.shape[0]), y])

    grad_fn = jax.grad(loss_fn)

    def step(_, params):
        g = grad_fn(params)
        return tuple(p - lr * gp for p, gp in zip(params, g))

    w1, w2 = jax.jit(lambda p: jax.lax.fori_loop(0, n_steps, step, p))((w1, w2))
    return np.asarray(w1), np.asarray(w2)


def quantize_master(w) -> np.ndarray:
    """Float layer -> 4-bit signed master codes with ONE per-layer scale.

    A single scale per layer (not per channel) preserves relative neuron
    magnitudes, so dropping the scale is argmax-neutral for the bias-free
    network."""
    w = np.asarray(w, np.float64)
    scale = max(float(np.abs(w).max()), 1e-9) / ((1 << (MASTER_WBITS - 1)) - 1)
    lo, hi = -(1 << (MASTER_WBITS - 1)), (1 << (MASTER_WBITS - 1)) - 1
    return np.clip(np.round(w / scale), lo, hi).astype(np.int32)


def effective_weights(master: np.ndarray, bits, margin) -> np.ndarray:
    """Per-column decode: truncate master codes to `bits`, snap within
    `margin`, rescale back to the master grid. `bits`/`margin` are arrays
    over the trailing (neuron) axis."""
    master = np.asarray(master, np.int32)
    bits = np.asarray(bits, np.int64)
    margin = np.asarray(margin, np.int64)
    out = np.zeros_like(master)
    for j in range(master.shape[1]):
        b, m = int(bits[j]), int(margin[j])
        sh = MASTER_WBITS - b
        code = master[:, j] >> sh          # arithmetic shift: round-to-floor
        lut = bespoke.snap_lut(b, m)
        out[:, j] = lut[code + (1 << (b - 1))] << sh
    return out


# ---------------------------------------------------------------------------
# accumulator widths + decode tables
# ---------------------------------------------------------------------------

def _max_abs_w() -> int:
    return 1 << (MASTER_WBITS - 1)


def acc1_bound(n_features: int) -> int:
    """Upper bound on a hidden accumulator (one sign of the (pos, neg) pair)."""
    return 255 * _max_abs_w() * n_features


def pick_shift(n_features: int, n_hidden: int) -> int:
    """Smallest static ReLU right-shift keeping layer-2 sums f32-exact."""
    sh = 0
    while (acc1_bound(n_features) >> sh) * _max_abs_w() * n_hidden >= (1 << 24):
        sh += 1
    return sh


def _acc_widths(n_features: int, n_hidden: int,
                shift: int) -> tuple[int, int, int]:
    """(hidden act bits, hidden out bits, output act bits) for the area model."""
    a1 = max(1, acc1_bound(n_features).bit_length())
    hid = max(1, (acc1_bound(n_features) >> shift).bit_length())
    a2 = max(1, ((acc1_bound(n_features) >> shift)
                 * _max_abs_w() * n_hidden).bit_length())
    return a1, hid, a2


def combo_tables(w1_master: np.ndarray, w2_master: np.ndarray, shift: int):
    """(TW1, TW2, COST1, COST2): per-combo effective weights + area quanta.

    TW1 (18, F, H) / TW2 (18, H, C) float32 hold exact small integers;
    COST1 (18, H) / COST2 (18, C) float32 hold integer `AREA_QUANTUM_MM2`
    counts — both exactly representable, so every fitness reduction over
    them is bit-exact under any order (DESIGN.md §11).
    """
    n_features, n_hidden = w1_master.shape
    n_classes = w2_master.shape[1]
    a1, hid, a2 = _acc_widths(n_features, n_hidden, shift)
    tw1 = np.zeros((N_COMBOS, n_features, n_hidden), np.float32)
    tw2 = np.zeros((N_COMBOS, n_hidden, n_classes), np.float32)
    cost1 = np.zeros((N_COMBOS, n_hidden), np.float32)
    cost2 = np.zeros((N_COMBOS, n_classes), np.float32)
    for b in range(WB_MIN, WB_MAX + 1):
        for m in range(N_MARGINS):
            k = (b - WB_MIN) * N_MARGINS + m
            e1 = effective_weights(w1_master, np.full(n_hidden, b),
                                   np.full(n_hidden, m))
            e2 = effective_weights(w2_master, np.full(n_classes, b),
                                   np.full(n_classes, m))
            tw1[k] = e1.astype(np.float32)
            tw2[k] = e2.astype(np.float32)
            cost1[k] = [area_mod.mlp_neuron_area_units(e1[:, j], 8, a1)
                        for j in range(n_hidden)]
            cost2[k] = [area_mod.mlp_neuron_area_units(e2[:, c], hid, a2)
                        for c in range(n_classes)]
    return tw1, tw2, cost1, cost2


# ---------------------------------------------------------------------------
# problem objects
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MLPOperands:
    """The lean fitness context: a pure pytree of arrays, stackable across
    same-shape problems for the sweep's vmapped buckets (like the tree
    family's `PaddedProblem`). Padding is inert by construction: padded
    hidden/output neurons carry all-zero TW/COST rows for every combo,
    padded classes are argmax-masked with an f32-exact sentinel, padded
    samples carry label -1 (never matched); accuracy divides by `n_valid`."""

    tw1: jnp.ndarray            # (18, F, H) f32 effective integer weights
    tw2: jnp.ndarray            # (18, H, C) f32
    cost1: jnp.ndarray          # (18, H) f32 integer area quanta
    cost2: jnp.ndarray          # (18, C) f32
    x8f: jnp.ndarray            # (B, F) f32 master input codes
    y: jnp.ndarray              # (B,) int32 (-1 on padded rows)
    class_valid: jnp.ndarray    # (C,) bool
    n_valid: jnp.ndarray        # () f32 real test-sample count
    shift_scale: jnp.ndarray    # () f32 = 2^-shift (exact power of two)
    exact_accuracy: jnp.ndarray  # () f32
    exact_area_mm2: jnp.ndarray  # () f32


jax.tree_util.register_pytree_node(
    MLPOperands,
    lambda p: (tuple(getattr(p, f.name)
                     for f in dataclasses.fields(MLPOperands)), None),
    lambda _, children: MLPOperands(*children),
)


@dataclasses.dataclass
class MLPProblem:
    """One dataset bound to a trained master-code MLP (host-side handle).

    The jax fitness paths run on `operands` (the lean pytree); the master
    codes + shift stay host-side for artifact writing, netlist lowering and
    serving. NOT itself a pytree — `search.engine` only touches `n_genes`
    and `exact_genes()`, and hands fitness construction back to the family.
    """

    w1_master: np.ndarray       # (F, H) int32 in [-8, 7]
    w2_master: np.ndarray       # (H, C) int32
    shift: int
    n_classes: int
    x8: np.ndarray              # (B, F) int32 master input codes
    y: np.ndarray               # (B,) int32
    exact_accuracy: float
    exact_area_mm2: float
    operands: MLPOperands

    @property
    def n_features(self) -> int:
        return int(self.w1_master.shape[0])

    @property
    def n_hidden(self) -> int:
        return int(self.w1_master.shape[1])

    @property
    def n_units(self) -> int:
        return self.n_hidden + self.n_classes

    @property
    def n_genes(self) -> int:
        return 2 * self.n_units

    def exact_genes(self) -> np.ndarray:
        return exact_genes(self.n_units)


def exact_genes(n_units: int) -> np.ndarray:
    """Chromosome decoding every neuron to (bits=4, margin=0) — the master
    codes unchanged, i.e. the exact design (mirrors `quant.exact_genes`)."""
    g = np.zeros(2 * n_units, np.float32)
    g[0::2] = 0.999
    g[1::2] = 0.0
    return g


def predict_master(w1, w2, shift: int, x8) -> np.ndarray:
    """Integer tensor oracle: (B, F) master codes -> (B,) argmax class."""
    h = np.asarray(x8, np.int64) @ np.asarray(w1, np.int64)
    hq = np.maximum(h, 0) >> shift
    s = hq @ np.asarray(w2, np.int64)
    return np.argmax(s, axis=1).astype(np.int32)


def build_problem(dataset, n_hidden: int = DEFAULT_HIDDEN,
                  n_steps: int = 300, seed: int = 0) -> MLPProblem:
    """Train + master-quantize the MLP for `dataset` (name or `Dataset`)."""
    from repro.datasets import load_dataset
    from repro.datasets.synthetic import quantize_u8

    ds = load_dataset(dataset) if isinstance(dataset, str) else dataset
    w1f, w2f = train_mlp(ds.x_train, ds.y_train, ds.n_classes,
                         n_hidden=n_hidden, seed=seed, n_steps=n_steps)
    w1m = quantize_master(w1f)
    w2m = quantize_master(w2f)
    n_features = ds.x_train.shape[1]
    if acc1_bound(n_features) >= (1 << 24):
        raise ValueError(
            f"{n_features} features overflow the f32-exact hidden "
            f"accumulator bound (needs 255*8*F < 2^24)")
    shift = pick_shift(n_features, n_hidden)
    tw1, tw2, cost1, cost2 = combo_tables(w1m, w2m, shift)

    x8 = quantize_u8(ds.x_test).astype(np.int32)
    y = np.asarray(ds.y_test, np.int32)
    pred = predict_master(w1m, w2m, shift, x8)
    # f32 arithmetic on the host so the exact chromosome scores EXACTLY
    # (0, 1) against the jnp fitness (f32 division / quantum multiply)
    exact_acc = float(np.float32((pred == y).sum())
                      / np.float32(x8.shape[0]))
    exact_units = float(cost1[EXACT_COMBO].sum() + cost2[EXACT_COMBO].sum())
    exact_area = max(float(np.float32(exact_units)
                           * np.float32(area_mod.AREA_QUANTUM_MM2)), 1e-9)

    operands = MLPOperands(
        tw1=jnp.asarray(tw1), tw2=jnp.asarray(tw2),
        cost1=jnp.asarray(cost1), cost2=jnp.asarray(cost2),
        x8f=jnp.asarray(x8, jnp.float32), y=jnp.asarray(y),
        class_valid=jnp.ones(ds.n_classes, bool),
        n_valid=jnp.float32(x8.shape[0]),
        shift_scale=jnp.float32(2.0 ** -shift),
        exact_accuracy=jnp.float32(exact_acc),
        exact_area_mm2=jnp.float32(exact_area),
    )
    return MLPProblem(
        w1_master=w1m, w2_master=w2m, shift=shift, n_classes=ds.n_classes,
        x8=x8, y=y, exact_accuracy=exact_acc, exact_area_mm2=exact_area,
        operands=operands)


# ---------------------------------------------------------------------------
# gene decode + fitness (reference and fused-kernel routes)
# ---------------------------------------------------------------------------

def decode_combos(genes):
    """(..., 2U) genes -> (..., U) int32 decode-table rows (18 combos).

    Per unit: bits = WB_MIN + clip(floor(g_bits * 3), 0, 2) and
    margin = clip(floor(g_margin * 6), 0, 5) — the comparator decode
    conventions of `core.quant.decode_genes` at the MLP's ranges."""
    span = WB_MAX - WB_MIN + 1
    gb, gm = genes[..., 0::2], genes[..., 1::2]
    bits = jnp.clip(jnp.floor(gb * span), 0, span - 1)
    marg = jnp.clip(jnp.floor(gm * N_MARGINS), 0, N_MARGINS - 1)
    return (bits * N_MARGINS + marg).astype(jnp.int32)


def decode_design(genes) -> tuple[np.ndarray, np.ndarray]:
    """Host decode: (2U,) genes -> (bits (U,), margin (U,)) int arrays."""
    combos = np.asarray(decode_combos(jnp.asarray(genes)))
    return (WB_MIN + combos // N_MARGINS).astype(np.int32), \
        (combos % N_MARGINS).astype(np.int32)


def _gather_weights(table, combos):
    """table (18, A, U) + combos (U,) -> (A, U) per-unit effective weights."""
    return jnp.take_along_axis(table, combos[None, None, :], axis=0)[0]


def _gather_cost(table, combos):
    """table (18, U) + combos (U,) -> (U,) per-unit area quanta."""
    return jnp.take_along_axis(table, combos[None, :], axis=0)[0]


def operand_objectives(ops: MLPOperands, genes):
    """(2*(H+C),) genes -> (acc loss, normalized area), both minimized.

    Exact integer arithmetic in f32 throughout (bounds in the module doc),
    argmax with first-max ties — bit-identical to the netlist and the
    kernel route.
    """
    n_hidden = ops.cost1.shape[-1]
    combos = decode_combos(genes)
    kh, ko = combos[:n_hidden], combos[n_hidden:]
    w1 = _gather_weights(ops.tw1, kh)
    w2 = _gather_weights(ops.tw2, ko)
    h = ops.x8f @ w1
    hq = jnp.floor(jnp.maximum(h, 0.0) * ops.shift_scale)
    s = hq @ w2
    s = jnp.where(ops.class_valid[None, :], s, _NEG_SENTINEL)
    pred = jnp.argmax(s, axis=1)
    acc = jnp.sum((pred == ops.y).astype(jnp.float32)) / ops.n_valid
    units = _gather_cost(ops.cost1, kh).sum() + _gather_cost(ops.cost2, ko).sum()
    areas = units * area_mod.AREA_QUANTUM_MM2
    return jnp.stack([ops.exact_accuracy - acc, areas / ops.exact_area_mm2])


def population_objectives(ops: MLPOperands, pop):
    """(P, 2U) -> (P, 2): the ctx-taking fitness for the sweep's vmap."""
    return jax.vmap(lambda g: operand_objectives(ops, g))(pop)


def make_reference_fitness(problem: MLPProblem):
    ops = problem.operands
    return jax.jit(lambda pop: population_objectives(ops, pop))


def make_kernel_fitness(problem: MLPProblem, *, interpret: bool | None = None,
                        **_unused):
    """Fused route: the population's first layer as ONE `qmatmul` launch.

    Per-chromosome effective weights gather from TW1 and concatenate on the
    output axis — `x8f (B, F) @ w (F, P*H) int8` — so the test set streams
    through the Pallas int8 matmul once per generation instead of once per
    chromosome. Everything stays integer-valued in f32, so the result is
    bit-identical to `make_reference_fitness` (pinned in tests).
    Extra kwargs (the tree backend's block sizes) are accepted and ignored.
    """
    from repro.kernels import ops as kops

    ops = problem.operands
    n_hidden, n_classes = problem.n_hidden, problem.n_classes

    def fitness(pop):
        p = pop.shape[0]
        combos = decode_combos(pop)                  # (P, H + C)
        kh, ko = combos[:, :n_hidden], combos[:, n_hidden:]
        w1 = jax.vmap(lambda k: _gather_weights(ops.tw1, k))(kh)  # (P, F, H)
        w2 = jax.vmap(lambda k: _gather_weights(ops.tw2, k))(ko)  # (P, H, C)
        wq = jnp.transpose(w1, (1, 0, 2)).reshape(-1, p * n_hidden)
        h = kops.qmatmul(ops.x8f, wq.astype(jnp.int8),
                         jnp.ones((p * n_hidden,), jnp.float32),
                         interpret=interpret)
        h = h.reshape(-1, p, n_hidden)
        hq = jnp.floor(jnp.maximum(h, 0.0) * ops.shift_scale)
        s = jnp.einsum("bph,phc->bpc", hq, w2)
        s = jnp.where(ops.class_valid[None, None, :], s, _NEG_SENTINEL)
        pred = jnp.argmax(s, axis=2)                 # (B, P)
        acc = (jnp.sum((pred == ops.y[:, None]).astype(jnp.float32), axis=0)
               / ops.n_valid)
        units = (jax.vmap(lambda k: _gather_cost(ops.cost1, k))(kh).sum(-1)
                 + jax.vmap(lambda k: _gather_cost(ops.cost2, k))(ko).sum(-1))
        areas = units * area_mod.AREA_QUANTUM_MM2
        return jnp.stack([ops.exact_accuracy - acc,
                          areas / ops.exact_area_mm2], axis=1)

    return jax.jit(fitness)


def make_kernel_predict(problem: MLPProblem, *, interpret: bool | None = None):
    """Single-chromosome (2U,) -> (B,) predictions through the qmatmul route
    — the kernel leg of the MLP oracle triangle (DESIGN.md §10/§15)."""
    from repro.kernels import ops as kops

    ops = problem.operands
    n_hidden = problem.n_hidden

    def predict(genes):
        combos = decode_combos(genes)
        kh, ko = combos[:n_hidden], combos[n_hidden:]
        w1 = _gather_weights(ops.tw1, kh)
        w2 = _gather_weights(ops.tw2, ko)
        h = kops.qmatmul(ops.x8f, w1.astype(jnp.int8),
                         jnp.ones((n_hidden,), jnp.float32),
                         interpret=interpret)
        hq = jnp.floor(jnp.maximum(h, 0.0) * ops.shift_scale)
        s = jnp.where(ops.class_valid[None, :], hq @ w2, _NEG_SENTINEL)
        return jnp.argmax(s, axis=1).astype(jnp.int32)

    return predict


# ---------------------------------------------------------------------------
# sweep padding (DESIGN.md §11): dims = (H, C, F, B)
# ---------------------------------------------------------------------------

def problem_dims(problem: MLPProblem) -> tuple[int, int, int, int]:
    return (problem.n_hidden, problem.n_classes, problem.n_features,
            int(problem.x8.shape[0]))


def pad_problem(problem: MLPProblem,
                dims: tuple[int, int, int, int]) -> MLPOperands:
    """Zero-pad the decode tables / dataset to bucket dims (inert padding:
    padded neurons have all-zero weights AND costs for every combo, so their
    genes can never move an objective bit)."""
    hp, cp, fp, bp = dims
    h, c, f, b = problem_dims(problem)
    if not (hp >= h and cp >= c and fp >= f and bp >= b):
        raise ValueError(f"padded dims {dims} smaller than problem dims "
                         f"{(h, c, f, b)}")
    ops = problem.operands
    tw1 = np.zeros((N_COMBOS, fp, hp), np.float32)
    tw1[:, :f, :h] = np.asarray(ops.tw1)
    tw2 = np.zeros((N_COMBOS, hp, cp), np.float32)
    tw2[:, :h, :c] = np.asarray(ops.tw2)
    cost1 = np.zeros((N_COMBOS, hp), np.float32)
    cost1[:, :h] = np.asarray(ops.cost1)
    cost2 = np.zeros((N_COMBOS, cp), np.float32)
    cost2[:, :c] = np.asarray(ops.cost2)
    x8f = np.zeros((bp, fp), np.float32)
    x8f[:b, :f] = np.asarray(ops.x8f)
    y = np.full(bp, -1, np.int32)
    y[:b] = problem.y
    class_valid = np.zeros(cp, bool)
    class_valid[:c] = True
    return MLPOperands(
        tw1=jnp.asarray(tw1), tw2=jnp.asarray(tw2),
        cost1=jnp.asarray(cost1), cost2=jnp.asarray(cost2),
        x8f=jnp.asarray(x8f), y=jnp.asarray(y),
        class_valid=jnp.asarray(class_valid),
        n_valid=jnp.float32(b),
        shift_scale=ops.shift_scale,
        exact_accuracy=ops.exact_accuracy,
        exact_area_mm2=ops.exact_area_mm2,
    )


# ---------------------------------------------------------------------------
# artifact schema (family-tagged pareto.json) + loader
# ---------------------------------------------------------------------------

MLP_REQUIRED_TOP_KEYS = frozenset({
    "family", "backend", "wall_s", "n_evaluations", "n_dispatches",
    "n_features", "n_hidden", "n_classes", "w1_master", "w2_master", "shift",
    "exact_accuracy", "exact_area_mm2", "rtl_verified", "pareto",
})
MLP_OPTIONAL_TOP_KEYS = frozenset({"dataset"})
MLP_REQUIRED_POINT_KEYS = frozenset({
    "acc_loss", "norm_area", "area_mm2", "area_netlist_mm2",
    "netlist_gates", "bits", "margin", "genes",
})
MLP_OPTIONAL_POINT_KEYS = frozenset({"rtl", "verified"})


def validate_payload(payload: dict, where: str = "payload") -> dict:
    """Two-way key-set + layout validation, mirroring `search.artifact`."""
    from repro.search.artifact import _check_keys

    if not isinstance(payload, dict):
        raise ValueError(f"pareto artifact {where}: expected a JSON object, "
                         f"got {type(payload).__name__}")
    _check_keys(payload, MLP_REQUIRED_TOP_KEYS, MLP_OPTIONAL_TOP_KEYS, where)
    if payload["family"] != "mlp":
        raise ValueError(f"pareto artifact {where}: family "
                         f"{payload['family']!r} is not 'mlp'")
    f, h, c = (payload["n_features"], payload["n_hidden"],
               payload["n_classes"])
    if len(payload["w1_master"]) != f or any(len(r) != h
                                             for r in payload["w1_master"]):
        raise ValueError(f"pareto artifact {where}: 'w1_master' must be "
                         f"{f} rows x {h} columns")
    if len(payload["w2_master"]) != h or any(len(r) != c
                                             for r in payload["w2_master"]):
        raise ValueError(f"pareto artifact {where}: 'w2_master' must be "
                         f"{h} rows x {c} columns")
    points = payload["pareto"]
    if not isinstance(points, list):
        raise ValueError(f"pareto artifact {where}: 'pareto' must be a list")
    for i, point in enumerate(points):
        if not isinstance(point, dict):
            raise ValueError(
                f"pareto artifact {where}: pareto[{i}] must be an object")
        _check_keys(point, MLP_REQUIRED_POINT_KEYS, MLP_OPTIONAL_POINT_KEYS,
                    f"{where}.pareto[{i}]")
        for key in ("bits", "margin"):
            if len(point[key]) != h + c:
                raise ValueError(
                    f"pareto artifact {where}: pareto[{i}].{key} has "
                    f"{len(point[key])} entries, expected {h + c} neurons")
    return payload


@dataclasses.dataclass
class MlpParetoArtifact:
    """A loaded, validated MLP `pareto.json`: master codes + pareto points.

    `point_design(i)` re-materializes point `i`'s EFFECTIVE integer weights
    from the masters + the point's per-neuron (bits, margin) through the
    same fixpoint snap tables the search decoded with — serving an artifact
    point reproduces its recorded accuracy bit-exactly."""

    payload: dict
    w1_master: np.ndarray       # (F, H) int32
    w2_master: np.ndarray       # (H, C) int32
    shift: int
    n_features: int
    n_hidden: int
    n_classes: int
    exact_accuracy: float
    exact_area_mm2: float
    dataset: str | None
    points: list
    family: str = "mlp"

    def point_design(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        """(w1_eff (F, H), w2_eff (H, C)) int32 effective weights of point i."""
        point = self.points[i]
        bits = np.asarray(point["bits"], np.int64)
        margin = np.asarray(point["margin"], np.int64)
        h = self.n_hidden
        w1 = effective_weights(self.w1_master, bits[:h], margin[:h])
        w2 = effective_weights(self.w2_master, bits[h:], margin[h:])
        return w1, w2

    def point_accuracy(self, i: int) -> float:
        return self.exact_accuracy - float(self.points[i]["acc_loss"])

    def best_under_loss(self, max_loss: float = 0.01) -> int | None:
        ok = [i for i, p in enumerate(self.points)
              if p["acc_loss"] <= max_loss + 1e-9]
        if not ok:
            return None
        return min(ok, key=lambda i: self.points[i]["norm_area"])


def artifact_from_payload(payload: dict,
                          where: str = "payload") -> MlpParetoArtifact:
    validate_payload(payload, where)
    return MlpParetoArtifact(
        payload=payload,
        w1_master=np.asarray(payload["w1_master"], np.int32),
        w2_master=np.asarray(payload["w2_master"], np.int32),
        shift=int(payload["shift"]),
        n_features=int(payload["n_features"]),
        n_hidden=int(payload["n_hidden"]),
        n_classes=int(payload["n_classes"]),
        exact_accuracy=float(payload["exact_accuracy"]),
        exact_area_mm2=float(payload["exact_area_mm2"]),
        dataset=payload.get("dataset"),
        points=list(payload["pareto"]),
    )


def write_artifact(problem: MLPProblem, result, out_dir: str, *,
                   emit_rtl: bool = False, verify_rtl: bool = False,
                   dataset: str | None = None) -> str:
    """MLP `pareto.json`: masters + decoded designs + hardware artifact.

    Per point: decoded per-neuron (bits, margin), the synthesized-netlist
    area/gate inventory, optional Verilog (`emit_rtl` — the generic
    gate-dump of `core.rtl.emit_circuit_verilog`), and the oracle-triangle
    assertion under `verify_rtl` (netlist sim == integer tensor predict ==
    qmatmul kernel route, over the full test set)."""
    from repro.core import rtl

    os.makedirs(out_dir, exist_ok=True)
    if emit_rtl:
        os.makedirs(os.path.join(out_dir, "rtl"), exist_ok=True)
    kernel_predict = make_kernel_predict(problem) if verify_rtl else None

    points = []
    for i, (o, g) in enumerate(zip(result.pareto_objs, result.pareto_genes)):
        bits, margin = decode_design(g)
        h = problem.n_hidden
        w1 = effective_weights(problem.w1_master, bits[:h], margin[:h])
        w2 = effective_weights(problem.w2_master, bits[h:], margin[h:])
        circuit = netlist.build_mlp_circuit(w1, w2, problem.shift,
                                            problem.n_classes)
        point = {
            "acc_loss": float(o[0]),
            "norm_area": float(o[1]),
            "area_mm2": float(o[1] * problem.exact_area_mm2),
            "area_netlist_mm2": round(netlist.netlist_area_mm2(circuit), 4),
            "netlist_gates": netlist.gate_counts(circuit),
            "bits": bits.tolist(),
            "margin": margin.tolist(),
            "genes": np.asarray(g, np.float64).round(6).tolist(),
        }
        if emit_rtl:
            verilog = rtl.emit_circuit_verilog(circuit,
                                               module_name="printed_mlp")
            rel = os.path.join("rtl", f"point_{i:02d}.v")
            with open(os.path.join(out_dir, rel), "w") as fh:
                fh.write(verilog)
            point["rtl"] = rel
        if verify_rtl:
            sim = np.asarray(netlist.simulate(circuit, problem.x8))
            ref = predict_master(w1, w2, problem.shift, problem.x8)
            ker = np.asarray(kernel_predict(jnp.asarray(g)))
            if not (np.array_equal(sim, ref) and np.array_equal(sim, ker)):
                n_ref = int((sim != ref).sum())
                n_ker = int((sim != ker).sum())
                raise AssertionError(
                    f"mlp pareto point {i}: netlist simulation diverges from "
                    f"the tensor predict on {n_ref} and from the kernel "
                    f"route on {n_ker} of {sim.shape[0]} test samples")
            point["verified"] = True
        points.append(point)

    payload = {
        "family": "mlp",
        "backend": result.backend,
        "wall_s": round(result.wall_s, 3),
        "n_evaluations": result.n_evaluations,
        "n_dispatches": result.n_dispatches,
        "n_features": problem.n_features,
        "n_hidden": problem.n_hidden,
        "n_classes": problem.n_classes,
        "w1_master": problem.w1_master.tolist(),
        "w2_master": problem.w2_master.tolist(),
        "shift": int(problem.shift),
        "exact_accuracy": problem.exact_accuracy,
        "exact_area_mm2": problem.exact_area_mm2,
        "rtl_verified": bool(verify_rtl),
        "pareto": points,
    }
    if dataset is not None:
        payload["dataset"] = dataset
    validate_payload(payload, where="mlp write_artifact")
    path = os.path.join(out_dir, "pareto.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(payload, fh, indent=1)
    os.replace(tmp, path)
    return path


# ---------------------------------------------------------------------------
# the family object
# ---------------------------------------------------------------------------

class PrintedMlpFamily(ClassifierFamily):
    """Integer-weight printed MLPs (arxiv 2402.02930 / 2312.17612 style)."""

    name = "mlp"

    def owns(self, problem) -> bool:
        return isinstance(problem, MLPProblem)

    def build_problem(self, dataset: str, n_hidden: int = DEFAULT_HIDDEN,
                      **opts):
        return build_problem(dataset, n_hidden=n_hidden, **opts)

    def n_genes(self, problem) -> int:
        return problem.n_genes

    def exact_genes(self, problem):
        return problem.exact_genes()

    def describe(self, problem) -> str:
        return (f"mlp: features={problem.n_features} "
                f"hidden={problem.n_hidden} classes={problem.n_classes} "
                f"shift={problem.shift} "
                f"exact_acc={problem.exact_accuracy:.3f}")

    def make_fitness(self, problem, backend: str = "reference", **kw):
        if backend == "reference":
            return make_reference_fitness(problem)
        if backend == "kernel":
            return make_kernel_fitness(problem, **kw)
        raise ValueError(f"unknown fitness backend {backend!r} for the "
                         f"mlp family")

    def problem_dims(self, problem) -> tuple:
        return problem_dims(problem)

    def pad_problem(self, problem, dims: tuple):
        return pad_problem(problem, dims)

    def population_objectives(self, padded, pop):
        return population_objectives(padded, pop)

    def padded_n_genes(self, dims: tuple) -> int:
        return 2 * (dims[0] + dims[1])

    def padded_exact_genes(self, dims: tuple):
        return exact_genes(dims[0] + dims[1])

    def unpad_genes(self, problem, genes, dims: tuple):
        hp = dims[0]
        idx = np.r_[0:2 * problem.n_hidden,
                    2 * hp:2 * hp + 2 * problem.n_classes]
        return genes[:, idx]

    def eval_cost(self, dims: tuple) -> float:
        hp, cp, fp, bp = dims
        return float(bp) * (fp * hp + hp * cp)

    def write_artifact(self, problem, result, out_dir: str, *,
                       emit_rtl: bool = False, verify_rtl: bool = False,
                       dataset: str | None = None) -> str:
        return write_artifact(problem, result, out_dir, emit_rtl=emit_rtl,
                              verify_rtl=verify_rtl, dataset=dataset)

    def load_artifact(self, payload_or_path):
        if isinstance(payload_or_path, str):
            with open(payload_or_path) as fh:
                payload = json.load(fh)
            return artifact_from_payload(payload, where=payload_or_path)
        return artifact_from_payload(payload_or_path)

    def make_server(self, artifact, point="best", max_loss: float = 0.01,
                    **opts):
        from repro.runtime.classify import ClassifyServer
        return ClassifyServer.from_artifact(artifact, point=point,
                                            max_loss=max_loss, **opts)

    def build_point_circuit(self, artifact, idx: int):
        w1, w2 = artifact.point_design(idx)
        return netlist.build_mlp_circuit(w1, w2, artifact.shift,
                                         artifact.n_classes)


FAMILY = PrintedMlpFamily()
