"""The decision-tree/forest family: `SearchProblem` behind the protocol.

This is the source paper's family (bespoke comparators, super-tree path
matmul, leaf-vote argmax) wrapped in the `ClassifierFamily` interface
(DESIGN.md §15) with ZERO behavioral change: every method delegates to the
pre-refactor modules (`search.problem`, `search.backends`, `search.engine`,
`search.sweep`, `runtime.classify`), so the tree path stays pinned bit-exact
array-for-array — `tests/test_search.py` / `test_sweep.py` /
`test_serve_classifier.py` pass unmodified on top of this wrapper.
"""
from __future__ import annotations

import numpy as np

from repro.core import quant
from repro.families.base import ClassifierFamily
from repro.search.problem import SearchProblem


class TreeFamily(ClassifierFamily):
    """Bespoke decision trees and bootstrap forests (paper arxiv 2203.08011)."""

    name = "tree"

    # -- problem construction + genes -------------------------------------

    def owns(self, problem) -> bool:
        return isinstance(problem, SearchProblem)

    def build_problem(self, dataset: str, n_trees: int = 1, **opts):
        from repro.core.forest import train_forest
        from repro.core.train import train_tree
        from repro.core.tree import to_parallel
        from repro.datasets import load_dataset
        from repro.search.problem import (build_forest_problem,
                                          build_tree_problem)

        ds = load_dataset(dataset)
        if n_trees <= 1:
            tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
            return build_tree_problem(to_parallel(tree), ds.x_test, ds.y_test)
        forest = train_forest(ds.x_train, ds.y_train, ds.n_classes,
                              n_trees=n_trees)
        return build_forest_problem(forest, ds.x_test, ds.y_test)

    def n_genes(self, problem) -> int:
        return problem.n_genes

    def exact_genes(self, problem):
        return problem.exact_genes()

    def describe(self, problem) -> str:
        kind = ("tree" if problem.n_trees == 1
                else f"forest[{problem.n_trees}]")
        return (f"{kind}: comparators={problem.n_comparators} "
                f"leaves={problem.n_leaves} "
                f"exact_acc={problem.exact_accuracy:.3f}")

    # -- fitness -----------------------------------------------------------

    def make_fitness(self, problem, backend: str = "reference", **kw):
        from repro.search import backends as _backends

        if backend == "reference":
            return _backends.make_reference_fitness(problem)
        if backend == "kernel":
            return _backends.make_kernel_fitness(problem, **kw)
        raise ValueError(f"unknown fitness backend {backend!r} for the "
                         f"tree family")

    # -- sweep padding (DESIGN.md §11) -------------------------------------

    def problem_dims(self, problem) -> tuple:
        from repro.search import sweep as _sweep
        return _sweep.problem_dims(problem)

    def pad_problem(self, problem, dims: tuple):
        from repro.search import sweep as _sweep
        return _sweep.pad_problem(problem, dims)

    def population_objectives(self, padded, pop):
        from repro.search import sweep as _sweep
        return _sweep.population_objectives(padded, pop)

    def padded_n_genes(self, dims: tuple) -> int:
        # cross-layer layout (DESIGN.md §16): 3 genes per padded comparator
        # slot + the trailing forest-level vote-adder gene
        return 3 * dims[0] + 1

    def padded_exact_genes(self, dims: tuple):
        return quant.exact_tree_genes(dims[0])

    def unpad_genes(self, problem, genes, dims: tuple):
        # real columns are the first 3N comparator genes plus the LAST
        # column (the vote gene sits at index 3*Np in the padded layout
        # but at 3*N in the real one — DESIGN.md §16)
        n_comp_genes = problem.n_genes - 1
        return np.concatenate([genes[:, :n_comp_genes], genes[:, -1:]],
                              axis=1)

    def eval_cost(self, dims: tuple) -> float:
        np_, lp, cp, fp, bp = dims
        return float(bp) * (np_ + np_ * lp + lp * cp)

    # -- artifacts + serving (DESIGN.md §10/§14) ---------------------------

    def write_artifact(self, problem, result, out_dir: str, *,
                       emit_rtl: bool = False, verify_rtl: bool = False,
                       dataset: str | None = None) -> str:
        from repro.search import engine as _engine
        return _engine.write_pareto_artifact(
            problem, result, out_dir, emit_rtl=emit_rtl,
            verify_rtl=verify_rtl, dataset=dataset)

    def load_artifact(self, payload_or_path):
        from repro.search import artifact as _artifact

        if isinstance(payload_or_path, str):
            return _artifact.load_pareto_artifact(payload_or_path)
        return _artifact.from_payload(payload_or_path)

    def make_server(self, artifact, point="best", max_loss: float = 0.01,
                    **opts):
        from repro.runtime.classify import ClassifyServer
        return ClassifyServer.from_artifact(artifact, point=point,
                                            max_loss=max_loss, **opts)

    def build_point_circuit(self, artifact, idx: int):
        from repro.core import netlist
        bits, t_int, trunc, vote_adder = artifact.point_design(idx)
        return netlist.build_circuit(artifact.ptrees(), bits, t_int,
                                     artifact.n_classes, trunc=trunc,
                                     vote_adder=vote_adder)


FAMILY = TreeFamily()
