#!/usr/bin/env python
"""Docs-consistency check: DESIGN.md section citations must resolve.

Module docstrings (and comments) cite architecture notes as ``DESIGN.md §N``.
Those section numbers are load-bearing — DESIGN.md promises they are stable —
so this check enforces, without importing any repo code:

  1. every ``DESIGN.md §N`` citation in a tracked .py file resolves to an
     existing ``## §N`` section of DESIGN.md         -> hard error (exit 1);
  2. every DESIGN.md section is cited by at least one module
     -> flagged; a warning by default, an error with --strict.

Run from the repo root (CI does):  python tools/check_design_refs.py
"""
from __future__ import annotations

import argparse
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
SECTION_RE = re.compile(r"^##\s*§(\d+)\s+(.*)$", re.MULTILINE)
# one DESIGN.md citation may name several sections ("DESIGN.md §7, §9")
CITE_RE = re.compile(r"DESIGN\.md\s*((?:§\d+[,/ ]*(?:and\s+)?)+)")
SECNUM_RE = re.compile(r"§(\d+)")


def design_sections(design_path: str) -> tuple[dict[int, str], list[int]]:
    """(sections, duplicated numbers). Duplicates break the 'section numbers
    are stable' promise — citations to them are ambiguous."""
    with open(design_path, encoding="utf-8") as f:
        text = f.read()
    sections: dict[int, str] = {}
    dups = []
    for m in SECTION_RE.finditer(text):
        num = int(m.group(1))
        if num in sections:
            dups.append(num)
        sections[num] = m.group(2).strip()
    return sections, dups


def iter_py_files():
    for base in SCAN_DIRS:
        root = os.path.join(REPO, base)
        if not os.path.isdir(root):
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for name in sorted(filenames):
                if name.endswith(".py"):
                    yield os.path.join(dirpath, name)


def collect_citations():
    """{section -> [(relpath, lineno), ...]}"""
    cites: dict[int, list[tuple[str, int]]] = {}
    for path in iter_py_files():
        rel = os.path.relpath(path, REPO)
        with open(path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                for m in CITE_RE.finditer(line):
                    for num in SECNUM_RE.findall(m.group(1)):
                        cites.setdefault(int(num), []).append((rel, lineno))
    return cites


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--strict", action="store_true",
                    help="uncited DESIGN.md sections fail instead of warn")
    args = ap.parse_args(argv)

    design_path = os.path.join(REPO, "DESIGN.md")
    if not os.path.exists(design_path):
        print("check_design_refs: DESIGN.md not found", file=sys.stderr)
        return 1
    sections, dups = design_sections(design_path)
    cites = collect_citations()

    failed = False
    for sec in sorted(set(dups)):
        failed = True
        print(f"ERROR: DESIGN.md defines §{sec} more than once — citations "
              f"to it are ambiguous", file=sys.stderr)
    for sec in sorted(set(cites) - set(sections)):
        failed = True
        for rel, lineno in cites[sec]:
            print(f"ERROR: {rel}:{lineno} cites DESIGN.md §{sec}, "
                  f"which does not exist", file=sys.stderr)

    uncited = sorted(set(sections) - set(cites))
    for sec in uncited:
        level = "ERROR" if args.strict else "WARN"
        print(f"{level}: DESIGN.md §{sec} ({sections[sec]}) is cited by no "
              f"module", file=sys.stderr)
    if args.strict and uncited:
        failed = True

    n_cites = sum(len(v) for v in cites.values())
    print(f"check_design_refs: {n_cites} citations across "
          f"{len(cites)} sections; DESIGN.md defines {len(sections)} "
          f"sections; {'FAIL' if failed else 'OK'}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
