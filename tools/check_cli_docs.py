#!/usr/bin/env python
"""Docs-drift gate: every public CLI flag must be documented.

`python -m repro.search` (plus its `sweep` and `serve` subcommands) is the
public entry point; README.md and API.md both carry flag tables. Flags have
drifted before (--family/--hidden/--mlp-datasets/--block-p landed in
README.md but not API.md), so this check enforces, without importing any
repo code:

  1. every `--flag` registered via `add_argument(...)` in
     src/repro/search/__main__.py appears in README.md  -> error;
  2. and in API.md                                      -> error;
  3. (--strict) every `--flag` mentioned in a doc's flag tables exists in
     the parsers — catches docs outliving a removed flag.

The parser source is scanned with `ast` rather than imported: the module
pulls in jax at import time and calls `parse_args` inside its entry
functions, and a docs gate should not need an accelerator stack.

Run from the repo root (CI does):  python tools/check_cli_docs.py
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CLI_SOURCE = os.path.join("src", "repro", "search", "__main__.py")
DOCS = ("README.md", "API.md")
# a documented flag is any `--word` token; tables write them as `--flag N`
DOC_FLAG_RE = re.compile(r"(--[a-z][a-z0-9-]*)")


def parser_flags(path: str) -> set[str]:
    """All `--option` strings passed to an .add_argument(...) call."""
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    flags: set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            continue
        for arg in node.args:
            if (isinstance(arg, ast.Constant) and isinstance(arg.value, str)
                    and arg.value.startswith("--")):
                flags.add(arg.value)
    return flags


def doc_flags(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        return set(DOC_FLAG_RE.findall(f.read()))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--strict", action="store_true",
                    help="documented flags unknown to the parsers fail too")
    args = ap.parse_args(argv)

    src = os.path.join(REPO, CLI_SOURCE)
    if not os.path.exists(src):
        print(f"check_cli_docs: {CLI_SOURCE} not found", file=sys.stderr)
        return 1
    flags = parser_flags(src)
    if not flags:
        print(f"check_cli_docs: no add_argument flags found in {CLI_SOURCE}",
              file=sys.stderr)
        return 1

    failed = False
    documented: set[str] = set()
    for doc in DOCS:
        doc_path = os.path.join(REPO, doc)
        if not os.path.exists(doc_path):
            print(f"check_cli_docs: {doc} not found", file=sys.stderr)
            return 1
        seen = doc_flags(doc_path)
        documented |= seen
        for flag in sorted(flags - seen):
            failed = True
            print(f"ERROR: {flag} ({CLI_SOURCE}) is undocumented in {doc}",
                  file=sys.stderr)

    # flags documented for OTHER CLIs (benchmarks.run, tools/check_*.py)
    other_clis = {"--quick", "--smoke", "--fitness-only", "--strict",
                  "--path", "--xla"}
    stale = sorted(documented - flags - other_clis)
    for flag in stale:
        level = "ERROR" if args.strict else "WARN"
        print(f"{level}: docs mention {flag}, which no "
              f"`python -m repro.search` parser registers", file=sys.stderr)
    if args.strict and stale:
        failed = True

    print(f"check_cli_docs: {len(flags)} parser flags checked against "
          f"{', '.join(DOCS)}; {'FAIL' if failed else 'OK'}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
