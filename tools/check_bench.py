#!/usr/bin/env python
"""Benchmark-artifact check: BENCH_search.json schema + speedup invariants.

`BENCH_search.json` is a committed measurement artifact (benchmarks/ga_bench
regenerates it); CI validates it without importing repo code so a regressed
or hand-mangled artifact fails loudly:

  1. schema: the expected sections exist with the expected per-row numeric
     fields (unknown extra fields are fine — the artifact may grow);
  2. invariant: `fused_ref_speedup_vs_looped` rows must not regress below
     1.0. Exception: rows at or past the documented fused-vs-looped
     arithmetic crossover (DESIGN.md §2 — the block-diagonal zeros stop
     paying for the saved dispatches around ~165 concatenated comparators)
     only need to stay above CROSSOVER_MIN_SPEEDUP, because re-measured
     artifacts legitimately land in the 0.9-1.1 band there;
  3. invariant: `dispatch_per_generation` rows must show the chunked driver
     dispatching strictly less often than the looped one (DESIGN.md §9);
  4. invariant: `fitness_pipeline` rows (DESIGN.md §12) must show the fused
     kernel's analytic HBM write traffic at least HBM_MIN_REDUCTION below
     the materializing path (deterministic — checked even in --smoke), the
     fused fitness kernel must actually beat the materializing scores path
     (FUSED_KERNEL_MIN_SPEEDUP — it measures 1.6-2.6x), and timing-stable
     rows (work >= FITNESS_FLOOR_MIN_WORK) must keep the hoisted-reference
     generation speedup inside the FITNESS_MIN_SPEEDUP no-regression band
     — small rows are dispatch/noise-bound on CPU, same reasoning as the
     crossover band.
  5. invariant: `sharded_search` rows (DESIGN.md §13) must show the
     hierarchical domination sort splitting the monolithic O(P²) pool
     pair-comparisons by exactly the shard count, a single dispatch per
     sharded run, and at least one >= SHARDED_MIN_SHARDS-way mesh row
     (deterministic — checked even in --smoke).
  6. invariant: `mlp_fitness` rows (DESIGN.md §15) must show the fused
     qmatmul route streaming the per-chromosome layer-1 weights as int8 —
     exactly MLP_W1_STREAM_REDUCTION below the reference path's f32 gather
     (deterministic — checked even in --smoke). The timing ratio is
     recorded, not gated: on CPU the kernel leg runs in Pallas interpret
     mode, so its wall-clock says nothing about TPU behavior.
  7. invariant: `serving` rows (DESIGN.md §14) must show steady-state
     serving allocating zero new device arrays and recompiling zero step
     programs after the ping-pong warmup, buckets on the power-of-two grid
     covering the batch (all deterministic — checked even in --smoke), and
     at-scale rows (batch >= SERVING_FLOOR_MIN_BATCH) keeping batched
     serving at least at per-sample parity with batch=1 dispatches.
  8. invariant: `fault_campaign` rows (DESIGN.md §17) must show the vmapped
     stuck-at simulator bit-exact against its two oracles — zero mismatches
     vs plain `simulate` on the empty-mask lane and vs the serial per-gate
     oracle on the sampled single-fault lanes — with every site covered by
     exactly two lanes (deterministic — checked even in --smoke); full runs
     additionally floor the vmapped-vs-serial fault throughput at
     FAULT_MIN_VMAPPED_SPEEDUP.

`--smoke` validates a freshly-measured artifact in CI: schema + the
deterministic invariants only (timing floors are meaningless on a shared
runner), and sections absent from the artifact are allowed (the smoke
benches emit only their own section — `fitness_pipeline` or
`sharded_search`).

Run from the repo root (CI does):  python tools/check_bench.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(REPO, "BENCH_search.json")

# DESIGN.md §2: vertebral[4] (165 comparators) sits at the crossover where
# fused-vs-looped hovers around parity across runs (measured 0.87-1.10).
CROSSOVER_N_COMPARATORS = 160
CROSSOVER_MIN_SPEEDUP = 0.85

# DESIGN.md §12: the hoisted-reference speedup floor applies to rows with
# enough per-generation work (n_samples * n_comparators) that CPU timing is
# stable; below it generations run ~1ms and the ratio is scheduler noise.
# Even at scale the CPU ratio hovers near parity (measured 0.95-1.05 across
# regenerations: XLA constant-folds much of the hoisted work off-TPU), so
# 0.9 is a no-regression band — the structural win the section exists for
# is the kernel path's deterministic HBM floor below.
FITNESS_FLOOR_MIN_WORK = 50_000
FITNESS_MIN_SPEEDUP = 0.9
# The fused kernel has beaten the materializing scores path by 1.6-2.6x in
# every measurement (fewer grid cells, no (P, B, C) round-trip); 1.0 is the
# hard "must actually be a speedup" floor.
FUSED_KERNEL_MIN_SPEEDUP = 1.0
# The fused kernel writes a lane-replicated (P, 128) accumulator instead of
# the (P, B_pad, C_pad) vote tensor: B_pad >= 256 and C_pad >= 128 make the
# analytic write reduction >= 256x for every real problem; 8x is a loose,
# deterministic floor.
HBM_MIN_REDUCTION = 8.0

SCHEMA = {
    "single_tree": {
        "dataset": str,
        "n_comparators": int,
        "us_per_chromosome_ref": float,
        "us_per_chromosome_kernel": float,
        "us_per_generation": float,
    },
    "forest": {
        "dataset": str,
        "n_trees": int,
        "n_comparators": int,
        "us_per_chromosome_looped": float,
        "us_per_chromosome_fused_ref": float,
        "us_per_chromosome_fused_kernel": float,
        "fused_ref_speedup_vs_looped": float,
    },
    "dispatch_per_generation": {
        "dataset": str,
        "pop": int,
        "n_generations": int,
        "dispatches_per_run_looped": int,
        "dispatches_per_run_chunked": int,
        "us_per_generation_looped": float,
        "us_per_generation_chunked": float,
        "chunked_speedup": float,
    },
    "fitness_pipeline": {
        "dataset": str,
        "n_trees": int,
        "n_comparators": int,
        "n_samples": int,
        "us_per_fitness_seed_ref": float,
        "us_per_fitness_hoisted_ref": float,
        "us_per_generation_seed": float,
        "us_per_generation_hoisted": float,
        "hoisted_generation_speedup": float,
        "us_per_chromosome_scores_kernel": float,
        "us_per_chromosome_fused_kernel": float,
        "fused_kernel_speedup_vs_scores": float,
        "hbm_bytes_per_eval_scores": int,
        "hbm_bytes_per_eval_fused": int,
        "hbm_write_reduction": float,
    },
    "sharded_search": {
        "dataset": str,
        "pop": int,
        "pop_per_shard": int,
        "n_shards": int,
        "n_generations": int,
        "dom_pairs_per_gen_monolithic": int,
        "dom_pairs_per_gen_per_shard": int,
        "dom_work_reduction_per_shard": float,
        "dispatches_per_run": int,
        "dispatches_per_generation": float,
        "us_per_generation": float,
    },
    "mlp_fitness": {
        "dataset": str,
        "n_features": int,
        "n_hidden": int,
        "n_classes": int,
        "n_samples": int,
        "us_per_chromosome_ref": float,
        "us_per_chromosome_kernel": float,
        "kernel_speedup_vs_ref": float,
        "w1_stream_bytes_per_eval_ref": int,
        "w1_stream_bytes_per_eval_kernel": int,
        "w1_stream_reduction": float,
    },
    "fault_campaign": {
        "dataset": str,
        "n_trees": int,
        "n_gates": int,
        "n_sites": int,
        "n_faults": int,
        "n_samples": int,
        "chunk": int,
        "faults_per_s_vmapped": float,
        "faults_per_s_serial": float,
        "vmapped_speedup_vs_serial": float,
        "zero_fault_mismatches": int,
        "single_fault_oracle_mismatches": int,
        "n_oracle_lanes": int,
    },
    "serving": {
        "dataset": str,
        "n_trees": int,
        "n_comparators": int,
        "n_classes": int,
        "batch": int,
        "bucket": int,
        "us_featurize_per_req": float,
        "us_batch_per_req": float,
        "us_classify_per_req": float,
        "us_total_per_req": float,
        "requests_per_s": float,
        "samples_per_s": float,
        "batched_speedup_vs_b1": float,
        "steady_state_new_arrays": int,
        "compiles_after_warmup": int,
        "n_steps": int,
    },
}

# DESIGN.md §14: serving rows with at least this many samples per request
# must show batched serving beating batch=1 dispatches per sample (the
# whole point of micro-batching is amortizing the dispatch), and the
# zero-realloc/zero-retrace steady-state invariants are deterministic —
# enforced in --smoke too.
SERVING_FLOOR_MIN_BATCH = 32
SERVING_MIN_BATCHED_SPEEDUP = 1.0

# DESIGN.md §17: the fault campaign's bit-exactness floors are analytic —
# the empty-mask lane must equal plain `simulate` on every test vector, the
# sampled vmapped lanes must equal the serial per-gate oracle, and each site
# contributes exactly a stuck-at-0 and a stuck-at-1 lane. The vmapped
# program batches fault lanes the serial loop walks one gate at a time, so
# even CPU smoke runs must keep it at least at parity.
FAULT_MIN_VMAPPED_SPEEDUP = 1.0

# DESIGN.md §15: the printed-MLP fused route streams the gathered layer-1
# weight stack to qmatmul as int8 (1 byte/weight, dequantized on-chip per
# tile); the reference einsum reads the f32 gather (4 bytes/weight). The
# ratio is exactly 4 by construction — analytic, enforced in --smoke too.
MLP_W1_STREAM_REDUCTION = 4.0

# DESIGN.md §13: the hierarchical sort hands each shard a (2P/S, 2P) row
# block of the pool domination matrix — an exact S-fold split of the
# monolithic (2P)² pair-comparisons — and the sharded chunk stays one
# lax.scan dispatch per run. Both are analytic, so enforced in --smoke too.
# The section must also demonstrate an actual multi-shard mesh (>= this
# many shards) or the weak-scaling ladder shows nothing.
SHARDED_MIN_SHARDS = 4


def check_rows(section: str, rows, errors: list[str]) -> None:
    want = SCHEMA[section]
    if not isinstance(rows, list) or not rows:
        errors.append(f"{section}: expected a non-empty list of rows")
        return
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errors.append(f"{section}[{i}]: expected an object")
            continue
        for field, typ in want.items():
            if field not in row:
                errors.append(f"{section}[{i}]: missing field {field!r}")
                continue
            val = row[field]
            ok = (isinstance(val, (int, float)) and not isinstance(val, bool)
                  if typ is float else isinstance(val, typ)
                  and not isinstance(val, bool))
            if not ok:
                errors.append(f"{section}[{i}].{field}: expected "
                              f"{typ.__name__}, got {type(val).__name__}")
            elif typ in (int, float) and field != "n_trees" and val < 0:
                errors.append(f"{section}[{i}].{field}: negative ({val})")


def check_speedups(bench: dict, min_speedup: float, errors: list[str]) -> None:
    for i, row in enumerate(bench.get("forest", [])):
        if not isinstance(row, dict):
            continue
        speedup = row.get("fused_ref_speedup_vs_looped")
        n = row.get("n_comparators", 0)
        if not isinstance(speedup, (int, float)):
            continue
        floor = (CROSSOVER_MIN_SPEEDUP if n >= CROSSOVER_N_COMPARATORS
                 else min_speedup)
        if speedup < floor:
            where = (f"near-crossover ({n} comparators >= "
                     f"{CROSSOVER_N_COMPARATORS})"
                     if n >= CROSSOVER_N_COMPARATORS else
                     f"below crossover ({n} comparators)")
            errors.append(
                f"forest[{i}] ({row.get('dataset')}[{row.get('n_trees')}]): "
                f"fused_ref_speedup_vs_looped={speedup:.3f} < {floor} "
                f"({where}) — the fused multi-tree path regressed vs the "
                f"looped oracle (DESIGN.md §2)")
    floored_rows = 0
    for i, row in enumerate(bench.get("fitness_pipeline", [])):
        if not isinstance(row, dict):
            continue
        kspeed = row.get("fused_kernel_speedup_vs_scores")
        if isinstance(kspeed, (int, float)) and kspeed < FUSED_KERNEL_MIN_SPEEDUP:
            errors.append(
                f"fitness_pipeline[{i}] ({row.get('dataset')}"
                f"[{row.get('n_trees')}]): fused_kernel_speedup_vs_scores="
                f"{kspeed:.3f} < {FUSED_KERNEL_MIN_SPEEDUP} — the §12 fused "
                f"fitness kernel no longer beats the materializing "
                f"tree_infer_scores path")
        speedup = row.get("hoisted_generation_speedup")
        if not isinstance(speedup, (int, float)):
            continue
        work = row.get("n_samples", 0) * row.get("n_comparators", 0)
        if work < FITNESS_FLOOR_MIN_WORK:
            continue  # dispatch/noise-bound on CPU (see module docstring)
        floored_rows += 1
        if speedup < FITNESS_MIN_SPEEDUP:
            errors.append(
                f"fitness_pipeline[{i}] ({row.get('dataset')}"
                f"[{row.get('n_trees')}]): hoisted_generation_speedup="
                f"{speedup:.3f} < {FITNESS_MIN_SPEEDUP} at work={work} — "
                f"the §12 hoisted reference path regressed vs the seed "
                f"formulation")
    if bench.get("fitness_pipeline") and floored_rows == 0:
        errors.append(
            "fitness_pipeline: no row reaches FITNESS_FLOOR_MIN_WORK="
            f"{FITNESS_FLOOR_MIN_WORK} — the section must include a "
            "timing-stable at-scale row (e.g. pendigits)")
    batched_rows = 0
    for i, row in enumerate(bench.get("serving", [])):
        if not isinstance(row, dict):
            continue
        batch = row.get("batch", 0)
        speedup = row.get("batched_speedup_vs_b1")
        if (not isinstance(batch, int)
                or not isinstance(speedup, (int, float))
                or batch < SERVING_FLOOR_MIN_BATCH):
            continue
        batched_rows += 1
        if speedup < SERVING_MIN_BATCHED_SPEEDUP:
            errors.append(
                f"serving[{i}] ({row.get('dataset')}[{row.get('n_trees')}] "
                f"batch={batch}): batched_speedup_vs_b1={speedup:.3f} < "
                f"{SERVING_MIN_BATCHED_SPEEDUP} — micro-batched serving no "
                f"longer amortizes the per-request dispatch (DESIGN.md §14)")
    if bench.get("serving") and batched_rows == 0:
        errors.append(
            f"serving: no row reaches batch >= {SERVING_FLOOR_MIN_BATCH} — "
            f"the section must include an at-scale batched row")
    for i, row in enumerate(bench.get("fault_campaign", [])):
        if not isinstance(row, dict):
            continue
        speedup = row.get("vmapped_speedup_vs_serial")
        if (isinstance(speedup, (int, float))
                and speedup < FAULT_MIN_VMAPPED_SPEEDUP):
            errors.append(
                f"fault_campaign[{i}] ({row.get('dataset')}"
                f"[{row.get('n_trees')}]): vmapped_speedup_vs_serial="
                f"{speedup:.3f} < {FAULT_MIN_VMAPPED_SPEEDUP} — the batched "
                f"fault simulator no longer beats the serial per-gate "
                f"oracle (DESIGN.md §17)")


def check_deterministic(bench: dict, errors: list[str]) -> None:
    """Floors that do not depend on wall-clock measurements — enforced in
    --smoke runs too."""
    for i, row in enumerate(bench.get("dispatch_per_generation", [])):
        if not isinstance(row, dict):
            continue
        looped = row.get("dispatches_per_run_looped")
        chunked = row.get("dispatches_per_run_chunked")
        if (isinstance(looped, int) and isinstance(chunked, int)
                and chunked >= looped):
            errors.append(
                f"dispatch_per_generation[{i}]: chunked dispatches "
                f"({chunked}) not below looped ({looped}) — the §9 "
                f"device-resident loop regressed")
    for i, row in enumerate(bench.get("fitness_pipeline", [])):
        if not isinstance(row, dict):
            continue
        red = row.get("hbm_write_reduction")
        scores = row.get("hbm_bytes_per_eval_scores")
        fused = row.get("hbm_bytes_per_eval_fused")
        if not all(isinstance(v, (int, float)) for v in (red, scores, fused)):
            continue
        if fused > 0 and abs(red - scores / fused) > 1e-6 * red:
            errors.append(
                f"fitness_pipeline[{i}]: hbm_write_reduction ({red}) does "
                f"not match bytes_scores/bytes_fused ({scores}/{fused})")
        if red < HBM_MIN_REDUCTION:
            errors.append(
                f"fitness_pipeline[{i}] ({row.get('dataset')}"
                f"[{row.get('n_trees')}]): hbm_write_reduction={red:.1f} < "
                f"{HBM_MIN_REDUCTION} — the §12 fused kernel no longer cuts "
                f"the O(P·B·C) vote-tensor write traffic")
    for i, row in enumerate(bench.get("mlp_fitness", [])):
        if not isinstance(row, dict):
            continue
        ref = row.get("w1_stream_bytes_per_eval_ref")
        ker = row.get("w1_stream_bytes_per_eval_kernel")
        red = row.get("w1_stream_reduction")
        if not all(isinstance(v, (int, float)) and not isinstance(v, bool)
                   for v in (ref, ker, red)):
            continue
        if ker <= 0 or abs(red - ref / ker) > 1e-6 * max(red, 1.0):
            errors.append(
                f"mlp_fitness[{i}]: w1_stream_reduction ({red}) does not "
                f"match ref/kernel bytes ({ref}/{ker})")
        elif red < MLP_W1_STREAM_REDUCTION:
            errors.append(
                f"mlp_fitness[{i}] ({row.get('dataset')}"
                f"[h={row.get('n_hidden')}]): w1_stream_reduction={red:.1f} "
                f"< {MLP_W1_STREAM_REDUCTION} — the §15 fused route no "
                f"longer streams layer-1 weights as int8")
    max_shards = 0
    for i, row in enumerate(bench.get("sharded_search", [])):
        if not isinstance(row, dict):
            continue
        s = row.get("n_shards")
        mono = row.get("dom_pairs_per_gen_monolithic")
        per = row.get("dom_pairs_per_gen_per_shard")
        red = row.get("dom_work_reduction_per_shard")
        disp = row.get("dispatches_per_run")
        if isinstance(s, int):
            max_shards = max(max_shards, s)
        if not all(isinstance(v, (int, float)) and not isinstance(v, bool)
                   for v in (s, mono, per, red, disp)):
            continue
        if per <= 0 or abs(red - mono / per) > 1e-6 * max(red, 1.0):
            errors.append(
                f"sharded_search[{i}]: dom_work_reduction_per_shard ({red}) "
                f"does not match monolithic/per_shard ({mono}/{per})")
        elif red < s:
            errors.append(
                f"sharded_search[{i}] (n_shards={s}): "
                f"dom_work_reduction_per_shard={red:.2f} < {s} — the §13 "
                f"hierarchical sort no longer splits the O(P²) pool "
                f"domination matrix across shards")
        if disp != 1:
            errors.append(
                f"sharded_search[{i}] (n_shards={s}): dispatches_per_run="
                f"{disp} != 1 — the sharded chunk is no longer a single "
                f"device-resident lax.scan (DESIGN.md §9/§13)")
    if bench.get("sharded_search") and max_shards < SHARDED_MIN_SHARDS:
        errors.append(
            f"sharded_search: max n_shards={max_shards} < "
            f"{SHARDED_MIN_SHARDS} — the weak-scaling ladder must include a "
            f">= {SHARDED_MIN_SHARDS}-way mesh row (simulate devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    for i, row in enumerate(bench.get("fault_campaign", [])):
        if not isinstance(row, dict):
            continue
        who = (f"fault_campaign[{i}] "
               f"({row.get('dataset')}[{row.get('n_trees')}])")
        zero = row.get("zero_fault_mismatches")
        if isinstance(zero, int) and zero != 0:
            errors.append(
                f"{who}: zero_fault_mismatches={zero} != 0 — the empty-mask "
                f"fault lane diverged from core.netlist.simulate "
                f"(DESIGN.md §17)")
        mism = row.get("single_fault_oracle_mismatches")
        if isinstance(mism, int) and mism != 0:
            errors.append(
                f"{who}: single_fault_oracle_mismatches={mism} != 0 — "
                f"vmapped stuck-at lanes diverged from the serial per-gate "
                f"oracle (DESIGN.md §17)")
        sites, n_faults = row.get("n_sites"), row.get("n_faults")
        if (isinstance(sites, int) and isinstance(n_faults, int)
                and n_faults != 2 * sites):
            errors.append(
                f"{who}: n_faults={n_faults} != 2 * n_sites={sites} — the "
                f"exhaustive campaign must cover stuck-at-0 AND stuck-at-1 "
                f"of every site (DESIGN.md §17)")
    for i, row in enumerate(bench.get("serving", [])):
        if not isinstance(row, dict):
            continue
        who = f"serving[{i}] ({row.get('dataset')}[{row.get('n_trees')}])"
        new_arrays = row.get("steady_state_new_arrays")
        if isinstance(new_arrays, int) and new_arrays != 0:
            errors.append(
                f"{who}: steady_state_new_arrays={new_arrays} != 0 — "
                f"steady-state serving reallocates; the donated ping-pong "
                f"slots no longer recycle their buffers (DESIGN.md §14)")
        recompiles = row.get("compiles_after_warmup")
        if isinstance(recompiles, int) and recompiles != 0:
            errors.append(
                f"{who}: compiles_after_warmup={recompiles} != 0 — "
                f"steady-state serving re-traces; bucket padding no longer "
                f"keeps request shapes on the compiled grid (DESIGN.md §14)")
        batch, bucket = row.get("batch"), row.get("bucket")
        if isinstance(batch, int) and isinstance(bucket, int):
            if bucket < batch or bucket < 1 or (bucket & (bucket - 1)):
                errors.append(
                    f"{who}: bucket={bucket} is not a power of two covering "
                    f"batch={batch} — request micro-batching left the "
                    f"power-of-two bucket grid (DESIGN.md §14)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=BENCH_PATH)
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="floor for below-crossover fused speedup rows")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode for freshly-measured artifacts: schema + "
                         "deterministic floors only, absent sections allowed")
    args = ap.parse_args(argv)

    try:
        with open(args.path) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: cannot read {args.path}: {e}")
        return 1

    errors: list[str] = []
    if not isinstance(bench.get("backend"), str):
        errors.append("top-level 'backend' must be a string")
    checked = 0
    for section in SCHEMA:
        if section not in bench or (args.smoke and not bench.get(section)):
            if not args.smoke:
                errors.append(f"missing section {section!r}")
            continue
        check_rows(section, bench[section], errors)
        checked += 1
    if args.smoke and checked == 0:
        errors.append("no known sections present")
    if not errors:
        check_deterministic(bench, errors)
        if not args.smoke:
            check_speedups(bench, args.min_speedup, errors)

    if errors:
        print(f"check_bench: {args.path} FAILED:")
        for e in errors:
            print(f"  - {e}")
        return 1
    n_rows = sum(len(bench.get(s) or []) for s in SCHEMA)
    mode = "smoke: deterministic floors" if args.smoke else \
        "fused/hoisted speedups, §9 dispatch counts, §12 HBM and " \
        "§13 shard-split floors"
    print(f"check_bench: OK ({n_rows} rows; {mode} within bounds)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
