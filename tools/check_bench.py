#!/usr/bin/env python
"""Benchmark-artifact check: BENCH_search.json schema + speedup invariants.

`BENCH_search.json` is a committed measurement artifact (benchmarks/ga_bench
regenerates it); CI validates it without importing repo code so a regressed
or hand-mangled artifact fails loudly:

  1. schema: the expected sections exist with the expected per-row numeric
     fields (unknown extra fields are fine — the artifact may grow);
  2. invariant: `fused_ref_speedup_vs_looped` rows must not regress below
     1.0. Exception: rows at or past the documented fused-vs-looped
     arithmetic crossover (DESIGN.md §2 — the block-diagonal zeros stop
     paying for the saved dispatches around ~165 concatenated comparators)
     only need to stay above CROSSOVER_MIN_SPEEDUP, because re-measured
     artifacts legitimately land in the 0.9-1.1 band there;
  3. invariant: `dispatch_per_generation` rows must show the chunked driver
     dispatching strictly less often than the looped one (DESIGN.md §9).

Run from the repo root (CI does):  python tools/check_bench.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(REPO, "BENCH_search.json")

# DESIGN.md §2: vertebral[4] (165 comparators) sits at the crossover where
# fused-vs-looped hovers around parity across runs (measured 0.87-1.10).
CROSSOVER_N_COMPARATORS = 160
CROSSOVER_MIN_SPEEDUP = 0.85

SCHEMA = {
    "single_tree": {
        "dataset": str,
        "n_comparators": int,
        "us_per_chromosome_ref": float,
        "us_per_chromosome_kernel": float,
        "us_per_generation": float,
    },
    "forest": {
        "dataset": str,
        "n_trees": int,
        "n_comparators": int,
        "us_per_chromosome_looped": float,
        "us_per_chromosome_fused_ref": float,
        "us_per_chromosome_fused_kernel": float,
        "fused_ref_speedup_vs_looped": float,
    },
    "dispatch_per_generation": {
        "dataset": str,
        "pop": int,
        "n_generations": int,
        "dispatches_per_run_looped": int,
        "dispatches_per_run_chunked": int,
        "us_per_generation_looped": float,
        "us_per_generation_chunked": float,
        "chunked_speedup": float,
    },
}


def check_rows(section: str, rows, errors: list[str]) -> None:
    want = SCHEMA[section]
    if not isinstance(rows, list) or not rows:
        errors.append(f"{section}: expected a non-empty list of rows")
        return
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errors.append(f"{section}[{i}]: expected an object")
            continue
        for field, typ in want.items():
            if field not in row:
                errors.append(f"{section}[{i}]: missing field {field!r}")
                continue
            val = row[field]
            ok = (isinstance(val, (int, float)) and not isinstance(val, bool)
                  if typ is float else isinstance(val, typ)
                  and not isinstance(val, bool))
            if not ok:
                errors.append(f"{section}[{i}].{field}: expected "
                              f"{typ.__name__}, got {type(val).__name__}")
            elif typ in (int, float) and field != "n_trees" and val < 0:
                errors.append(f"{section}[{i}].{field}: negative ({val})")


def check_speedups(bench: dict, min_speedup: float, errors: list[str]) -> None:
    for i, row in enumerate(bench.get("forest", [])):
        if not isinstance(row, dict):
            continue
        speedup = row.get("fused_ref_speedup_vs_looped")
        n = row.get("n_comparators", 0)
        if not isinstance(speedup, (int, float)):
            continue
        floor = (CROSSOVER_MIN_SPEEDUP if n >= CROSSOVER_N_COMPARATORS
                 else min_speedup)
        if speedup < floor:
            where = (f"near-crossover ({n} comparators >= "
                     f"{CROSSOVER_N_COMPARATORS})"
                     if n >= CROSSOVER_N_COMPARATORS else
                     f"below crossover ({n} comparators)")
            errors.append(
                f"forest[{i}] ({row.get('dataset')}[{row.get('n_trees')}]): "
                f"fused_ref_speedup_vs_looped={speedup:.3f} < {floor} "
                f"({where}) — the fused multi-tree path regressed vs the "
                f"looped oracle (DESIGN.md §2)")
    for i, row in enumerate(bench.get("dispatch_per_generation", [])):
        if not isinstance(row, dict):
            continue
        looped = row.get("dispatches_per_run_looped")
        chunked = row.get("dispatches_per_run_chunked")
        if (isinstance(looped, int) and isinstance(chunked, int)
                and chunked >= looped):
            errors.append(
                f"dispatch_per_generation[{i}]: chunked dispatches "
                f"({chunked}) not below looped ({looped}) — the §9 "
                f"device-resident loop regressed")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=BENCH_PATH)
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="floor for below-crossover fused speedup rows")
    args = ap.parse_args(argv)

    try:
        with open(args.path) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: cannot read {args.path}: {e}")
        return 1

    errors: list[str] = []
    if not isinstance(bench.get("backend"), str):
        errors.append("top-level 'backend' must be a string")
    for section in SCHEMA:
        if section not in bench:
            errors.append(f"missing section {section!r}")
        else:
            check_rows(section, bench[section], errors)
    if not errors:
        check_speedups(bench, args.min_speedup, errors)

    if errors:
        print(f"check_bench: {args.path} FAILED:")
        for e in errors:
            print(f"  - {e}")
        return 1
    n_rows = sum(len(bench[s]) for s in SCHEMA)
    print(f"check_bench: OK ({n_rows} rows; fused speedups and §9 dispatch "
          f"counts within bounds)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
