"""End-to-end LM training driver: data pipeline -> sharded train loop ->
checkpoint/restart -> resume.

Trains a small decoder LM (a reduced config of any assigned arch) on the
synthetic corpus, checkpoints every N steps, then simulates a crash and
resumes from the last checkpoint — the production fault-tolerance loop in
miniature. Run bigger configs / more steps on real hardware with the same
flags.

    PYTHONPATH=src python examples/lm_train.py --arch llama3.2-3b \
        --steps 60 --d-model 256 --layers 4
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data import SyntheticLMData
from repro.models import transformer
from repro.optim import get_optimizer, warmup_cosine_schedule, adamw
from repro.runtime import checkpoint, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_train_ckpt")
    args = ap.parse_args()

    cfg = reduced_config(
        get_config(args.arch), d_model=args.d_model, n_layers=args.layers,
        d_ff=4 * args.d_model, vocab_size=args.vocab,
        loss_chunk=args.batch * args.seq // 4)
    n_text = args.seq - cfg.prefix_len

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={args.arch} (reduced) params={n_params/1e6:.1f}M "
          f"tokens/step={args.batch * n_text}")

    sched = warmup_cosine_schedule(3e-3, 10, args.steps)
    opt = adamw(schedule=sched)
    step_fn = jax.jit(train.make_train_step(cfg, optimizer=opt))
    state = train.init_train_state(params, opt)
    data = SyntheticLMData(cfg.vocab_size, n_text, args.batch, seed=7)

    def batch_for(step):
        b = data.batch(step)
        out = {"tokens": jnp.asarray(b["tokens"])}
        if cfg.prefix_len:
            out["prefix_embed"] = jnp.zeros(
                (args.batch, cfg.prefix_len, cfg.d_model), jnp.float32)
        return out

    crash_at = args.steps // 2
    t0 = time.time()
    for step in range(crash_at):
        state, metrics = step_fn(state, batch_for(step))
        if step % args.ckpt_every == 0 or step == crash_at - 1:
            checkpoint.save(args.ckpt_dir, step, state)
        if step % 10 == 0:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")

    print(f"\n!! simulated crash at step {crash_at}; restarting from last "
          f"checkpoint")
    last = checkpoint.latest_step(args.ckpt_dir)
    state2 = train.init_train_state(params, opt)
    state2, restored_step = checkpoint.restore(args.ckpt_dir, last, state2)
    print(f"resumed at step {int(state2.step)} (checkpoint {restored_step})")

    for step in range(int(state2.step), args.steps):
        state2, metrics = step_fn(state2, batch_for(step))
        if step % 10 == 0:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f}")
    print(f"\nfinal loss {float(metrics['loss']):.4f} "
          f"(started ~{np.log(cfg.vocab_size):.2f}); "
          f"total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
