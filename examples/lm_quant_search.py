"""The paper's technique carried to the LM zoo: NSGA-II mixed-precision
search over per-tensor (bits, snap-margin) genes — the comparator chromosome
applied to matmul weights (DESIGN.md §5, repro.quantize).

Trains a tiny LM briefly, then searches the (accuracy loss, hardware cost)
space. Cost = bytes + CSD multiplier cost of the snapped codes; the quantized
codes are what kernels.qmatmul executes at serving time.

    PYTHONPATH=src python examples/lm_quant_search.py --arch gemma-2b
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core import nsga2
from repro.data import SyntheticLMData
from repro.models import lm, transformer
from repro.optim import adamw
from repro.quantize import make_lm_quant_problem, quantizable_tensors
from repro.runtime import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--pop", type=int, default=16)
    ap.add_argument("--gens", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch), d_model=128, n_layers=3,
                         d_ff=512, vocab_size=2048, prefix_len=0,
                         loss_chunk=2048)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)

    # brief training so quantization has real structure to preserve
    opt = adamw(lr=3e-3)
    step_fn = jax.jit(train.make_train_step(cfg, optimizer=opt))
    state = train.init_train_state(params, opt)
    data = SyntheticLMData(cfg.vocab_size, 128, 16, seed=1)
    for s in range(args.train_steps):
        state, metrics = step_fn(state, {"tokens": jnp.asarray(
            data.batch(s)["tokens"])})
    params = state.params
    print(f"trained tiny {args.arch}: loss {float(metrics['loss']):.3f}")

    eval_batch = {"tokens": jnp.asarray(data.batch(10_000)["tokens"])}
    loss_fn = jax.jit(lambda p, b: lm.lm_loss(p, cfg, b)[0])
    fitness, n_genes, base = make_lm_quant_problem(params, cfg, eval_batch,
                                                   loss_fn)
    n_tensors = len(quantizable_tensors(params))
    print(f"searching {n_tensors} tensors ({n_genes} genes), "
          f"float loss {base:.3f}")

    ga = nsga2.NSGA2Config(pop_size=args.pop, n_generations=args.gens)
    state = nsga2.run(jax.random.PRNGKey(0),
                      lambda g: jnp.asarray(fitness(np.asarray(g))),
                      n_genes, ga, jit=False)
    objs, genes = nsga2.pareto_front(state.objs, state.genes)
    print("\npareto (loss increase, cost vs bf16):")
    for o in objs:
        print(f"  dloss={o[0]:+.4f}  cost={o[1]:.3f} "
              f"({1/max(o[1],1e-9):.2f}x smaller than bf16)")
    ok = objs[objs[:, 0] <= 0.02]
    if len(ok):
        best = ok[ok[:, 1].argmin()]
        print(f"\n@<=0.02 loss increase: {1/best[1]:.2f}x cost reduction "
              f"— the paper's area-accuracy trade carried to the LM zoo")


if __name__ == "__main__":
    main()
