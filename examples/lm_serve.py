"""Serving example: batched prefill + autoregressive decode with preallocated
caches — the serve_step lowered by the decode_* dry-run cells, on CPU scale.

    PYTHONPATH=src python examples/lm_serve.py --arch mamba2-1.3b --tokens 24
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.runtime import lm_serve as serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    n_text = args.prompt_len - cfg.prefix_len
    prompt = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, n_text), 0, cfg.vocab_size)}
    if cfg.prefix_len:
        prompt["prefix_embed"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.prefix_len, cfg.d_model), jnp.float32)

    s_max = args.prompt_len + args.tokens + 8
    t0 = time.time()
    out = serve.generate(params, cfg, prompt, n_tokens=args.tokens,
                         s_max=s_max)
    dt = time.time() - t0
    print(f"arch={args.arch} (reduced) batch={args.batch} "
          f"prompt={args.prompt_len} generated={args.tokens}")
    print(f"throughput: {args.batch * args.tokens / dt:.1f} tok/s "
          f"(CPU, includes compile)")
    print("first sequences:", np.asarray(out)[:2].tolist())


if __name__ == "__main__":
    main()
