"""Quickstart: the paper's pipeline end-to-end on one dataset.

Train an exact bespoke Decision Tree, run the NSGA-II dual-approximation
search, print the pareto front, pick the best design under a 1% accuracy-loss
budget, and emit its bespoke Verilog.

    PYTHONPATH=src python examples/quickstart.py [--dataset seeds]
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.datasets import load_dataset
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.core import approx, area, nsga2, quant, rtl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="seeds")
    ap.add_argument("--pop", type=int, default=64)
    ap.add_argument("--gens", type=int, default=40)
    args = ap.parse_args()

    print(f"== {args.dataset}: train exact bespoke DT ==")
    ds = load_dataset(args.dataset)
    tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
    pt = to_parallel(tree)
    prob = approx.build_problem(pt, ds.x_test, ds.y_test)
    print(f"comparators={pt.n_comparators} leaves={pt.n_leaves} "
          f"test_acc={prob.exact_accuracy:.3f} "
          f"area={prob.exact_area_mm2:.1f}mm^2 "
          f"power={area.power_mw(prob.exact_area_mm2):.2f}mW")

    print(f"== NSGA-II search (pop={args.pop}, gens={args.gens}) ==")
    fit = approx.make_fitness_fn(prob)
    cfg = nsga2.NSGA2Config(pop_size=args.pop, n_generations=args.gens)
    state = nsga2.run(jax.random.PRNGKey(0), fit, prob.n_genes, cfg)
    objs, genes = nsga2.pareto_front(state.objs, state.genes)

    print("pareto front (acc_loss, normalized area):")
    for o in objs:
        print(f"  {o[0]:+.4f}  {o[1]:.3f}  ({1/max(o[1],1e-9):.2f}x smaller)")

    ok = [(o, g) for o, g in zip(objs, genes) if o[0] <= 0.01]
    o, g = min(ok, key=lambda t: t[0][1]) if ok else (objs[0], genes[0])
    a_mm2 = o[1] * prob.exact_area_mm2
    print(f"\nselected @<=1% loss: area={a_mm2:.1f}mm^2 "
          f"({1/o[1]:.2f}x), power={area.power_mw(a_mm2):.2f}mW "
          f"{'< 3mW: printed-battery OK' if area.power_mw(a_mm2) < 3 else ''}")

    bits, marg = quant.decode_genes(jnp.asarray(g))
    t_int = quant.substitute(
        quant.threshold_to_int(jnp.asarray(pt.threshold), bits), marg, bits)
    verilog = rtl.emit_verilog(pt, np.asarray(bits), np.asarray(t_int))
    out = f"/tmp/bespoke_{args.dataset}.v"
    with open(out, "w") as f:
        f.write(verilog)
    print(f"bespoke RTL written to {out} ({len(verilog.splitlines())} lines)")


if __name__ == "__main__":
    main()
