"""Quickstart: the paper's pipeline end-to-end on one dataset.

Train an exact bespoke Decision Tree (or a random forest with --trees K),
run the NSGA-II dual-approximation search through the unified engine
(`repro.search.run_search`), print the pareto front, pick the best design
under a 1% accuracy-loss budget, and emit its bespoke Verilog — for forests
too: per-tree vote modules plus the majority-vote adder tree, verified
against the gate-level netlist simulator (DESIGN.md §10).

    PYTHONPATH=src python examples/quickstart.py [--dataset seeds]
    PYTHONPATH=src python examples/quickstart.py --backend kernel --trees 4

(The same flow is packaged as ``python -m repro.search``.)
"""
import argparse

import numpy as np
import jax.numpy as jnp

from repro.datasets import load_dataset
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.core.forest import train_forest
from repro.core import area, netlist, rtl
from repro import search


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="seeds")
    ap.add_argument("--backend", default="reference",
                    choices=list(search.BACKENDS))
    ap.add_argument("--trees", type=int, default=1)
    ap.add_argument("--pop", type=int, default=64)
    ap.add_argument("--gens", type=int, default=40)
    args = ap.parse_args()

    print(f"== {args.dataset}: train exact bespoke "
          f"{'DT' if args.trees <= 1 else f'{args.trees}-tree RF'} ==")
    ds = load_dataset(args.dataset)
    if args.trees <= 1:
        tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
        prob = search.build_tree_problem(to_parallel(tree), ds.x_test,
                                         ds.y_test)
    else:
        forest = train_forest(ds.x_train, ds.y_train, ds.n_classes,
                              n_trees=args.trees)
        prob = search.build_forest_problem(forest, ds.x_test, ds.y_test)
    print(f"comparators={prob.n_comparators} leaves={prob.n_leaves} "
          f"test_acc={prob.exact_accuracy:.3f} "
          f"area={prob.exact_area_mm2:.1f}mm^2 "
          f"power={area.power_mw(prob.exact_area_mm2):.2f}mW")

    print(f"== NSGA-II search (backend={args.backend}, pop={args.pop}, "
          f"gens={args.gens}) ==")
    result = search.run_search(prob, backend=args.backend, pop_size=args.pop,
                               n_generations=args.gens)

    print("pareto front (acc_loss, normalized area):")
    for o in result.pareto_objs:
        print(f"  {o[0]:+.4f}  {o[1]:.3f}  ({1/max(o[1],1e-9):.2f}x smaller)")

    best = result.best_under_loss(0.01)
    if best is None:
        best = result.pareto_objs[0], result.pareto_genes[0]
    o, g = best
    a_mm2 = o[1] * prob.exact_area_mm2
    print(f"\nselected @<=1% loss: area={a_mm2:.1f}mm^2 "
          f"({1/o[1]:.2f}x), power={area.power_mw(a_mm2):.2f}mW "
          f"{'< 3mW: printed-battery OK' if area.power_mw(a_mm2) < 3 else ''}")

    # effective design: decode_chromosome folds comparator truncation into
    # the returned precision/threshold (DESIGN.md §16), so lowering it with
    # trunc unset is identical to lowering the pre-truncation design
    bits, t_int, vote_cap = search.decode_chromosome(prob, jnp.asarray(g))
    vote_adder = "approx" if np.isfinite(float(vote_cap)) else "exact"
    ptrees = search.problem_ptrees(prob)
    verilog = rtl.emit_design(ptrees, np.asarray(bits), np.asarray(t_int),
                              prob.n_classes, vote_adder=vote_adder)
    out = f"/tmp/bespoke_{args.dataset}.v"
    with open(out, "w") as f:
        f.write(verilog)
    print(f"bespoke RTL written to {out} ({len(verilog.splitlines())} lines)")

    # the hardware oracle: gate-level netlist sim vs the tensor program
    circuit = netlist.build_circuit(ptrees, np.asarray(bits),
                                    np.asarray(t_int), prob.n_classes,
                                    vote_adder=vote_adder)
    sim = np.asarray(netlist.simulate(circuit, prob.x8))
    ref = np.asarray(search.predict_votes(prob, bits, t_int, vote_cap))
    assert np.array_equal(sim, ref), "netlist simulation diverged"
    counts = netlist.gate_counts(circuit)
    print(f"netlist verified on {sim.shape[0]} samples: "
          f"{circuit.n_gates} gates {counts}, "
          f"actual area {netlist.netlist_area_mm2(circuit):.1f}mm^2 "
          f"vs LUT estimate {a_mm2:.1f}mm^2")


if __name__ == "__main__":
    main()
