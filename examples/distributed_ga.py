"""Island-model distributed NSGA-II with fault injection + checkpoint/restart.

Runs the paper's search as it would run on a multi-pod TPU fleet, scaled down
to N host devices: one island per device, ring elite-migration, a checkpoint
every round, then a simulated failure and an ELASTIC restart on fewer devices
from the last checkpoint.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_ga.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.datasets import load_dataset
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.core import approx, dist, nsga2
from repro.runtime import checkpoint


def main():
    n_dev = len(jax.devices())
    print(f"devices: {n_dev} (islands)")
    ds = load_dataset("cardio")
    tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
    pt = to_parallel(tree)
    prob = approx.build_problem(pt, ds.x_test, ds.y_test)
    fit = approx.make_fitness_fn(prob)
    print(f"cardio: {pt.n_comparators} comparators, "
          f"exact acc {prob.exact_accuracy:.3f}")

    mesh = Mesh(np.array(jax.devices()).reshape(n_dev), ("data",))
    cfg = dist.IslandConfig(local_pop=24, migrate_every=4, n_migrate=3)

    ckpt_dir = tempfile.mkdtemp(prefix="ga_ckpt_")
    state = dist.init_islands(jax.random.PRNGKey(0), fit, prob.n_genes,
                              mesh, cfg)
    step = dist.make_island_step(fit, mesh, cfg)
    for rnd in range(4):
        state = step(state)
        checkpoint.save(ckpt_dir, rnd, state)
        objs, _ = dist.gathered_pareto(state)
        best = objs[objs[:, 0] <= 0.01]
        area = best[:, 1].min() if len(best) else float("nan")
        print(f"round {rnd}: pareto={len(objs)} best_area@1%={area:.3f}")

    # ---- simulated pod failure: restart on HALF the devices --------------
    print("\n!! simulating failure: restarting on half the islands "
          "from the last checkpoint (elastic)")
    half = n_dev // 2
    mesh2 = Mesh(np.array(jax.devices()[:half]).reshape(half), ("data",))
    like = jax.tree.map(lambda a: np.asarray(a), state)
    spec = nsga2.NSGA2State(genes=P("data"), objs=P("data"), rank=P("data"),
                            crowd=P("data"), key=P("data"), generation=P())
    shardings = jax.tree.map(lambda s: NamedSharding(mesh2, s), spec,
                             is_leaf=lambda x: isinstance(x, P))
    last = checkpoint.latest_step(ckpt_dir)
    state2, _ = checkpoint.restore(ckpt_dir, last, like, shardings=shardings)
    # population re-shards onto the smaller mesh; islands continue
    step2 = dist.make_island_step(fit, mesh2, cfg)
    for rnd in range(2):
        state2 = step2(state2)
        objs, _ = dist.gathered_pareto(state2)
        best = objs[objs[:, 0] <= 0.01]
        area = best[:, 1].min() if len(best) else float("nan")
        print(f"post-failure round {rnd}: pareto={len(objs)} "
              f"best_area@1%={area:.3f}")
    print("elastic restart OK — search state survived the failure")


if __name__ == "__main__":
    main()
