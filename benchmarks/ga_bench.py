"""GA throughput benchmark (paper §IV: slowest single-chromosome fitness
3.08 ms on HAR). Ours is population-vectorized: we report amortized
us-per-chromosome-evaluation for the reference (vmap) and Pallas-kernel
fitness paths, plus one full NSGA-II generation."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.paper_tables import build_all
from repro.core import approx, nsga2


def _timeit(fn, *args, repeat=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def run(datasets=("har", "pendigits", "seeds"), pop=64):
    rows = []
    built = build_all(datasets)
    for name, (ds, tree, pt, prob) in built.items():
        genes = jax.random.uniform(jax.random.PRNGKey(0), (pop, prob.n_genes))
        f_ref = approx.make_fitness_fn(prob)
        t_ref = _timeit(f_ref, genes)
        f_ker = approx.make_fitness_fn_kernel(prob, pt, ds.n_features)
        t_ker = _timeit(f_ker, genes)
        step = jax.jit(nsga2.make_step(
            f_ref, nsga2.NSGA2Config(pop_size=pop, n_generations=1)))
        state = nsga2.init_state(jax.random.PRNGKey(1), f_ref, prob.n_genes,
                                 nsga2.NSGA2Config(pop_size=pop))
        t_gen = _timeit(step, state)
        rows.append({
            "dataset": name,
            "n_comparators": pt.n_comparators,
            "us_per_chromosome_ref": 1e6 * t_ref / pop,
            "us_per_chromosome_kernel": 1e6 * t_ker / pop,
            "us_per_generation": 1e6 * t_gen,
            "paper_ms_per_chromosome_har": 3.08,
        })
    return rows
