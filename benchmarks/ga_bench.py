"""GA throughput benchmark (paper §IV: slowest single-chromosome fitness
3.08 ms on HAR). Ours is population-vectorized: we report amortized
us-per-chromosome-evaluation for the unified search engine's `reference`
(vmap) and `kernel` (fused Pallas) backends, plus one full NSGA-II
generation.

`ga.forest_*` rows compare the OLD K-iteration per-tree Python loop
(`core.forest.forest_predict`, one small program per tree) against the fused
block-diagonal super-tree evaluation (`repro.search`): reference backend =
one vote-matmul tensor program, kernel backend = ONE Pallas launch for the
entire population x test-set x forest product. The (dataset, n_trees) specs
deliberately ladder the comparator count so the fused-vs-looped crossover
(DESIGN.md §2) shows as a trend.

`ga.dispatch_*` rows measure the host-dispatch overhead the device-resident
generation loop (DESIGN.md §9) removes: N per-generation jitted dispatches
vs one `nsga2.make_chunk` lax.scan.

`ga.sharded_*` rows measure the mesh-sharded NSGA-II (DESIGN.md §13) as a
weak-scaling ladder: the per-shard population slab is held fixed while the
shard count grows, so each row's per-shard domination work — the (2P, 2P)
pool pair-comparisons a shard actually evaluates, (2P)²/S rows vs the
monolithic (2P)² — stays proportional to one device's budget. The work
split is analytic and floor-checked in CI smoke runs; the whole sharded run
stays ONE dispatch (a lax.scan over the shard_map'd generation), reported
per generation alongside the measured wall-clock.

`ga.fitness_*` rows measure the fused fitness pipeline (DESIGN.md §12):
the pre-§12 generation program (feature gather re-stated per evaluation,
one decode per objective term, sequential-loop crowding) vs the hoisted
one (`x_sel` precomputed on the problem, one shared decode, vmapped
crowding), and the materializing `tree_infer_scores` kernel path vs the
fused `fitness_errors` kernel — plus the *analytic* HBM bytes each kernel
writes per fitness evaluation (O(P·B·C) vote tensor vs the O(P) error
accumulator), which is deterministic and floor-checked in CI smoke runs.
`ga.mlp_*` rows measure the printed-MLP family's fitness routes
(DESIGN.md §15): pure-jnp reference vs the fused `qmatmul` route that
evaluates the whole population's first layer as ONE int8 Pallas launch,
with the analytic layer-1 weight-stream bytes (int8 tiles dequantized
on-chip vs the f32 table gather) floor-checked in CI smoke runs.

Results are also emitted as a BENCH_search.json artifact (see
`write_artifact` / benchmarks.run).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.paper_tables import build_all
from repro.core import forest as forest_mod
from repro.core import nsga2, quant
from repro.datasets import load_dataset
from repro import search

ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "BENCH_search.json")


def _timeit(fn, *args, repeat=5):
    out = fn(*args)  # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def _timeit_pair(fn_a, fn_b, args_a, args_b, trials=6, min_batch_s=0.03):
    """Best-of timing of two programs with ALTERNATING batches.

    Timing A's trials in one block and B's in another lets clock-frequency
    drift between the blocks bias the A/B ratio by more than the effect
    being measured; alternating batches exposes both programs to the same
    drift. The per-batch repeat count is auto-scaled so one batch runs at
    least `min_batch_s`, keeping per-call noise amortized for microsecond-
    scale programs. Returns (best_a, best_b) per-call seconds."""
    t_a = _timeit(fn_a, *args_a, repeat=1)  # compile + rough scale
    t_b = _timeit(fn_b, *args_b, repeat=1)
    rep_a = max(3, int(min_batch_s / max(t_a, 1e-9)))
    rep_b = max(3, int(min_batch_s / max(t_b, 1e-9)))
    best_a, best_b = t_a, t_b
    for _ in range(trials):
        best_a = min(best_a, _timeit(fn_a, *args_a, repeat=rep_a))
        best_b = min(best_b, _timeit(fn_b, *args_b, repeat=rep_b))
    return best_a, best_b


def _looped_forest_fitness(forest, problem):
    """The historical forest fitness: a Python loop of K per-tree programs
    (gather + small matmul each), kept here as the benchmark baseline the
    fused engine is measured against. Decodes the cross-layer 3N+1 gene
    layout (DESIGN.md §16) — truncation folded into effective operands,
    saturating vote cap — so it computes the same function as the fused
    paths, one tree program at a time."""
    x8 = problem.x8
    y = problem.y
    thresholds = jnp.concatenate(
        [jnp.asarray(p.threshold) for p in forest.ptrees])
    exact_acc = problem.exact_accuracy
    exact_area = problem.exact_area_mm2
    lut, offsets = problem.area_lut, problem.lut_offsets
    overhead = problem.overhead_mm2
    vote_exact = jnp.float32(problem.vote_mm2_exact)
    vote_approx = jnp.float32(problem.vote_mm2_approx)
    n_classes = forest.n_classes

    @jax.jit
    def fitness(pop):
        def one(genes):
            from repro.core.tree import leaves_from_decisions

            bits, marg, trunc, vote = quant.decode_tree_genes(genes)
            t_sub = quant.substitute(
                quant.threshold_to_int(thresholds, bits), marg, bits)
            bits_eff = bits - trunc
            t_eff = jnp.right_shift(t_sub, trunc)
            votes = jnp.zeros((x8.shape[0], n_classes), jnp.float32)
            off = 0
            for pt in forest.ptrees:
                n = pt.n_comparators
                x_g = x8[:, jnp.asarray(pt.feature)]
                x_p = quant.inputs_at_precision(x_g, bits_eff[off:off + n])
                d = x_p > t_eff[None, off:off + n]
                leaf = leaves_from_decisions(d, jnp.asarray(pt.path),
                                             jnp.asarray(pt.path_len))
                cls = jnp.asarray(pt.leaf_class)[leaf]
                votes = votes + jax.nn.one_hot(cls, n_classes)
                off += n
            vote_cap = jnp.where(vote > 0, jnp.float32(1.0),
                                 jnp.float32(jnp.inf))
            pred = jnp.argmax(jnp.minimum(votes, vote_cap), axis=1)
            acc = jnp.mean((pred == y).astype(jnp.float32))
            a = lut[offsets[bits_eff] + t_eff].sum() + overhead
            a = a + jnp.where(jnp.isfinite(vote_cap), vote_approx, vote_exact)
            return jnp.stack([exact_acc - acc, a / exact_area])
        return jax.vmap(one)(pop)

    return fitness


def run(datasets=("har", "pendigits", "seeds"), pop=64):
    """Single-tree rows: reference vs kernel backend + one GA generation."""
    rows = []
    built = build_all(datasets)
    for name, (ds, tree, pt, prob) in built.items():
        genes = jax.random.uniform(jax.random.PRNGKey(0), (pop, prob.n_genes))
        f_ref = search.make_fitness(prob, "reference")
        t_ref = _timeit(f_ref, genes)
        f_ker = search.make_fitness(prob, "kernel")
        t_ker = _timeit(f_ker, genes)
        step = jax.jit(nsga2.make_step(
            f_ref, nsga2.NSGA2Config(pop_size=pop, n_generations=1)))
        state = nsga2.init_state(jax.random.PRNGKey(1), f_ref, prob.n_genes,
                                 nsga2.NSGA2Config(pop_size=pop))
        t_gen = _timeit(step, state)
        rows.append({
            "dataset": name,
            "n_comparators": pt.n_comparators,
            "us_per_chromosome_ref": 1e6 * t_ref / pop,
            "us_per_chromosome_kernel": 1e6 * t_ker / pop,
            "us_per_generation": 1e6 * t_gen,
            "paper_ms_per_chromosome_har": 3.08,
        })
    return rows


FOREST_SPECS = (("seeds", 4), ("vertebral", 2), ("vertebral", 4))


def run_forest(specs=FOREST_SPECS, pop=64):
    """Forest rows: looped per-tree baseline vs fused engine backends.

    The fused rows evaluate the whole forest population with NO per-tree
    Python loop — `kernel` is one Pallas program (grid = population x
    batch-blocks x leaf-blocks). `specs` is (dataset, n_trees) pairs; the
    vertebral[2] row sits between the seeds[4] and vertebral[4] comparator
    counts so the fused-vs-looped crossover (DESIGN.md §2) is visible as a
    trend, not a cliff."""
    rows = []
    for name, n_trees in specs:
        ds = load_dataset(name)
        forest = forest_mod.train_forest(ds.x_train, ds.y_train, ds.n_classes,
                                         n_trees=n_trees)
        prob = search.build_forest_problem(forest, ds.x_test, ds.y_test)
        genes = jax.random.uniform(jax.random.PRNGKey(0), (pop, prob.n_genes))
        f_loop = _looped_forest_fitness(forest, prob)
        f_ref = search.make_fitness(prob, "reference")
        f_ker = search.make_fitness(prob, "kernel")
        t_loop = _timeit(f_loop, genes)
        t_ref = _timeit(f_ref, genes)
        t_ker = _timeit(f_ker, genes)
        rows.append({
            "dataset": name,
            "n_trees": n_trees,
            "n_comparators": prob.n_comparators,
            "us_per_chromosome_looped": 1e6 * t_loop / pop,
            "us_per_chromosome_fused_ref": 1e6 * t_ref / pop,
            "us_per_chromosome_fused_kernel": 1e6 * t_ker / pop,
            "fused_ref_speedup_vs_looped": t_loop / t_ref,
        })
    return rows


def _seed_reference_fitness(problem):
    """The pre-§12 reference formulation, kept as the benchmark baseline:
    the chromosome-invariant feature gather is (re)stated inside the vmapped
    objective and each objective term runs its own gene decode — exactly
    what `search.objectives` computed before the hoisted fitness pipeline."""

    @jax.jit
    def fitness(pop):
        def one(genes):
            bits, margin, trunc, vote = quant.decode_tree_genes(genes)
            t_int = quant.threshold_to_int(problem.threshold, bits)
            t_sub = quant.substitute(t_int, margin, bits)
            bits_eff = bits - trunc
            t_eff = jnp.right_shift(t_sub, trunc)
            x_g = problem.x8[:, problem.feature]
            x_p = quant.inputs_at_precision(x_g, bits_eff)
            d = (x_p > t_eff[None, :]).astype(jnp.float32)
            score = d @ problem.path.T.astype(jnp.float32)
            target = (problem.path_len - problem.n_neg).astype(jnp.float32)
            sat = (score == target[None, :]).astype(jnp.float32)
            cls1h = jax.nn.one_hot(problem.leaf_class, problem.n_classes)
            vote_cap = jnp.where(vote > 0, jnp.float32(1.0),
                                 jnp.float32(jnp.inf))
            pred = jnp.argmax(jnp.minimum(sat @ cls1h, vote_cap), axis=1)
            acc = jnp.mean((pred == problem.y).astype(jnp.float32))
            # historical double decode for the area term
            bits2, margin2, trunc2, vote2 = quant.decode_tree_genes(genes)
            t_sub2 = quant.substitute(
                quant.threshold_to_int(problem.threshold, bits2),
                margin2, bits2)
            area = problem.area_lut[
                problem.lut_offsets[bits2 - trunc2]
                + jnp.right_shift(t_sub2, trunc2)].sum()
            area = area + problem.overhead_mm2
            area = area + jnp.where(vote2 > 0,
                                    jnp.float32(problem.vote_mm2_approx),
                                    jnp.float32(problem.vote_mm2_exact))
            return jnp.stack([problem.exact_accuracy - acc,
                              area / problem.exact_area_mm2])
        return jax.vmap(one)(pop)

    return fitness


def _loop_crowding_distance(objs, rank):
    """The pre-§12 crowding distance: a Python loop of M sequential masked
    sorts (one program per objective) — `nsga2.crowding_distance` now runs
    the same arithmetic vmapped over the objective axis."""
    p, m = objs.shape
    dist = jnp.zeros((p,), dtype=jnp.float32)
    for k in range(m):
        v = objs[:, k]
        key = rank.astype(jnp.float32) * nsga2._BIG + v
        order = jnp.argsort(key)
        v_s = v[order]
        r_s = rank[order]
        prev_ok = jnp.concatenate([jnp.array([False]), r_s[1:] == r_s[:-1]])
        next_ok = jnp.concatenate([r_s[:-1] == r_s[1:], jnp.array([False])])
        v_prev = jnp.concatenate([v_s[:1], v_s[:-1]])
        v_next = jnp.concatenate([v_s[1:], v_s[-1:]])
        fmin = jnp.full((p,), jnp.inf).at[r_s].min(v_s)
        fmax = jnp.full((p,), -jnp.inf).at[r_s].max(v_s)
        span = jnp.maximum((fmax - fmin)[r_s], 1e-12)
        d = jnp.where(prev_ok & next_ok, (v_next - v_prev) / span, jnp.inf)
        dist = dist.at[order].add(jnp.where(jnp.isinf(d), nsga2._BIG, d))
    return dist


def _seed_make_step(fitness_fn, cfg):
    """The pre-§12 generation program: seed fitness + loop crowding. The
    benchmark baseline `hoisted_generation_speedup` is measured against —
    everything else (tournament, SBX, mutation, sort, truncation) is the
    live `nsga2` code."""

    def step(state):
        p, g = state.genes.shape
        p_mut = 1.0 / g
        key, ksel, kx, km = jax.random.split(state.key, 4)
        idx = nsga2._tournament(ksel, state.rank, state.crowd, p)
        pa, pb = state.genes[idx[0::2]], state.genes[idx[1::2]]
        o1, o2 = nsga2._sbx(kx, pa, pb, cfg.eta_crossover, cfg.p_crossover)
        children = jnp.concatenate([o1, o2], axis=0)[:p]
        children = nsga2._poly_mutation(km, children, cfg.eta_mutation, p_mut)
        c_objs = fitness_fn(children)
        pool_genes = jnp.concatenate([state.genes, children], axis=0)
        pool_objs = jnp.concatenate([state.objs, c_objs], axis=0)
        rank = nsga2.non_dominated_sort(pool_objs)
        crowd = _loop_crowding_distance(pool_objs, rank)
        order = jnp.argsort(rank.astype(jnp.float32) * nsga2._BIG
                            - jnp.minimum(crowd, nsga2._BIG / 2))
        keep = order[:p]
        return nsga2.NSGA2State(
            pool_genes[keep], pool_objs[keep], rank[keep], crowd[keep],
            key, state.generation + 1)

    return step


def _hbm_bytes_per_eval(problem, pop, block_b=256, block_p=8):
    """Analytic HBM *write* traffic per fitness evaluation (f32 words).

    The materializing path writes the full (P, B_pad, C_pad) vote tensor;
    the fused path writes only the lane-replicated (P_pad, 128) correct-count
    accumulator (DESIGN.md §12). Deterministic — floor-checked in CI."""
    def pad(x, m):
        return x + (-x) % m
    b_pad = pad(int(problem.x8.shape[0]), block_b)
    c_pad = pad(problem.n_classes, 128)
    p_pad = pad(pop, block_p)
    scores = 4 * pop * b_pad * c_pad
    fused = 4 * p_pad * 128
    return scores, fused


# seeds = the tiny dispatch-bound row, pendigits = the stable at-scale row
# (B=3298, N=225: generations run hundreds of ms, so the seed-vs-hoisted
# ratio is timing-stable), seeds[4] = the forest layout.
FITNESS_SPECS = (("seeds", 1), ("pendigits", 1), ("seeds", 4))


def run_fitness_pipeline(specs=FITNESS_SPECS, pop=64):
    """Fused fitness pipeline rows (DESIGN.md §12): seed vs hoisted
    reference through one full NSGA-II generation (the seed generation is
    the whole pre-§12 program — seed fitness AND the sequential-loop
    crowding distance), materializing vs fused kernel fitness, and the
    analytic HBM write traffic of each."""
    rows = []
    for name, n_trees in specs:
        ds = load_dataset(name)
        if n_trees <= 1:
            from repro.core.train import train_tree
            from repro.core.tree import to_parallel
            pt = to_parallel(train_tree(ds.x_train, ds.y_train, ds.n_classes))
            prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
        else:
            forest = forest_mod.train_forest(ds.x_train, ds.y_train,
                                             ds.n_classes, n_trees=n_trees)
            prob = search.build_forest_problem(forest, ds.x_test, ds.y_test)
        genes = jax.random.uniform(jax.random.PRNGKey(0), (pop, prob.n_genes))
        cfg = nsga2.NSGA2Config(pop_size=pop, n_generations=1)

        f_seed = _seed_reference_fitness(prob)
        f_hoist = search.make_fitness(prob, "reference")
        t_seed_fit, t_hoist_fit = _timeit_pair(f_seed, f_hoist,
                                               (genes,), (genes,))

        state = nsga2.init_state(jax.random.PRNGKey(1), f_hoist, prob.n_genes,
                                 nsga2.NSGA2Config(pop_size=pop))
        step_seed = jax.jit(_seed_make_step(f_seed, cfg))
        step_hoist = jax.jit(nsga2.make_step(f_hoist, cfg))
        t_seed_gen, t_hoist_gen = _timeit_pair(step_seed, step_hoist,
                                               (state,), (state,))

        f_scores = _scores_kernel_fitness(prob)
        f_fused = search.make_fitness(prob, "kernel")
        t_scores, t_fused = _timeit_pair(f_scores, f_fused, (genes,),
                                         (genes,), trials=2, min_batch_s=0.0)
        hbm_scores, hbm_fused = _hbm_bytes_per_eval(prob, pop)

        rows.append({
            "dataset": name,
            "n_trees": n_trees,
            "n_comparators": prob.n_comparators,
            "n_samples": int(prob.x8.shape[0]),
            "us_per_fitness_seed_ref": 1e6 * t_seed_fit,
            "us_per_fitness_hoisted_ref": 1e6 * t_hoist_fit,
            "us_per_generation_seed": 1e6 * t_seed_gen,
            "us_per_generation_hoisted": 1e6 * t_hoist_gen,
            "hoisted_generation_speedup": t_seed_gen / t_hoist_gen,
            "us_per_chromosome_scores_kernel": 1e6 * t_scores / pop,
            "us_per_chromosome_fused_kernel": 1e6 * t_fused / pop,
            "fused_kernel_speedup_vs_scores": t_scores / t_fused,
            "hbm_bytes_per_eval_scores": hbm_scores,
            "hbm_bytes_per_eval_fused": hbm_fused,
            "hbm_write_reduction": hbm_scores / hbm_fused,
        })
    return rows


MLP_FITNESS_SPECS = (("seeds", 8), ("vertebral", 8))


def run_mlp_fitness(specs=MLP_FITNESS_SPECS, pop=64):
    """Printed-MLP family fitness rows (DESIGN.md §15): the pure-jnp
    reference route vs the fused `kops.qmatmul` route (the population's
    first layer as ONE int8 Pallas launch), plus the *analytic* layer-1
    weight-stream traffic of each — the qmatmul streams the gathered
    per-chromosome W1 stack as int8 (1 byte/weight, dequantized on-chip
    per tile) where the reference einsum reads the f32 gather
    (4 bytes/weight). The byte counts are deterministic and floor-checked
    in CI smoke runs; the timing ratio is recorded, not gated — on CPU
    the kernel leg runs in Pallas interpret mode and the ratio says
    nothing about TPU behavior."""
    from repro.families import printed_mlp as pm

    rows = []
    for name, n_hidden in specs:
        prob = pm.build_problem(name, n_hidden=n_hidden)
        genes = jax.random.uniform(jax.random.PRNGKey(0), (pop, prob.n_genes))
        f_ref = pm.make_reference_fitness(prob)
        f_ker = pm.make_kernel_fitness(prob)
        t_ref, t_ker = _timeit_pair(f_ref, f_ker, (genes,), (genes,),
                                    trials=2, min_batch_s=0.0)
        w1_words = pop * prob.n_features * prob.n_hidden
        rows.append({
            "dataset": name,
            "n_features": prob.n_features,
            "n_hidden": prob.n_hidden,
            "n_classes": prob.n_classes,
            "n_samples": int(prob.x8.shape[0]),
            "us_per_chromosome_ref": 1e6 * t_ref / pop,
            "us_per_chromosome_kernel": 1e6 * t_ker / pop,
            "kernel_speedup_vs_ref": t_ref / t_ker,
            "w1_stream_bytes_per_eval_ref": 4 * w1_words,
            "w1_stream_bytes_per_eval_kernel": w1_words,
            "w1_stream_reduction": 4.0,
        })
    return rows


def _scores_kernel_fitness(problem):
    """The pre-§12 kernel fitness: `tree_infer_scores` materializes the
    (P, B, C) vote tensor to HBM, argmax + label compare + area decode run
    outside the kernel (with the historical double decode)."""
    from repro.kernels import ops as kops

    operands = kops.prepare_operands(
        problem.feature, problem.path, problem.path_len, problem.n_neg,
        problem.leaf_class, problem.n_classes, problem.n_features)
    threshold = problem.threshold

    @jax.jit
    def fitness(pop):
        scale, thr, vote_cap = kops.decode_population(threshold, pop)
        preds = kops.tree_infer_predict(problem.x8, operands, scale, thr,
                                        vote_cap)
        acc = jnp.mean((preds == problem.y[None, :]).astype(jnp.float32),
                       axis=1)
        # historical double decode for the area term
        scale2, t_sub2, bits2, vote_cap2 = kops.decode_population_full(
            threshold, pop)
        areas = problem.area_lut[
            problem.lut_offsets[bits2] + t_sub2].sum(axis=1)
        areas = areas + problem.overhead_mm2
        areas = areas + jnp.where(jnp.isfinite(vote_cap2),
                                  jnp.float32(problem.vote_mm2_approx),
                                  jnp.float32(problem.vote_mm2_exact))
        return jnp.stack(
            [problem.exact_accuracy - acc, areas / problem.exact_area_mm2],
            axis=1,
        )

    return fitness


def run_dispatch(datasets=("seeds",), pop=64, gens=20):
    """Host-dispatch overhead rows (DESIGN.md §9): one jitted step per
    generation (the pre-§9 driver, `gens` host round-trips) vs ONE
    `nsga2.make_chunk` lax.scan for the whole run (a single dispatch).
    The arithmetic is identical — the gap is pure dispatch overhead."""
    rows = []
    built = build_all(datasets)
    for name, (ds, tree, pt, prob) in built.items():
        f_ref = search.make_fitness(prob, "reference")
        cfg = nsga2.NSGA2Config(pop_size=pop, n_generations=gens)
        state = nsga2.init_state(jax.random.PRNGKey(0), f_ref, prob.n_genes,
                                 cfg)
        step = jax.jit(nsga2.make_step(f_ref, cfg))

        def looped(s):
            for _ in range(gens):
                s = step(s)
            return s

        chunk = jax.jit(nsga2.make_chunk(f_ref, cfg, gens))
        t_loop = _timeit(looped, state)
        t_chunk = _timeit(chunk, state)
        rows.append({
            "dataset": name,
            "pop": pop,
            "n_generations": gens,
            "dispatches_per_run_looped": gens,
            "dispatches_per_run_chunked": 1,
            "us_per_generation_looped": 1e6 * t_loop / gens,
            "us_per_generation_chunked": 1e6 * t_chunk / gens,
            "dispatch_overhead_us_per_generation": 1e6 * (t_loop - t_chunk) / gens,
            "chunked_speedup": t_loop / t_chunk,
        })
    return rows


SHARD_COUNTS = (1, 2, 4, 8)


def run_sharded(dataset="seeds", pop_per_shard=32, gens=8,
                shard_counts=SHARD_COUNTS):
    """Mesh-sharded NSGA-II weak-scaling rows (DESIGN.md §13).

    Per-shard population held at ``pop_per_shard`` while the shard count
    grows; the n_shards=1 row is the single-device `nsga2.make_chunk`
    oracle, every other row the `dist.make_sharded_chunk` shard_map at the
    same total population. The per-shard domination work columns are
    analytic — hierarchical domination gives each shard a (2P/S, 2P) row
    block of the (2P, 2P) pool matrix, an exact S-fold split — and the
    dispatch columns record that the sharded run is still one lax.scan
    dispatch for the whole chunk. Shard counts beyond the host device count
    are skipped (simulate with XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    from repro.core import dist
    from repro.launch.mesh import make_search_mesh

    rows = []
    built = build_all((dataset,))
    ds, tree, pt, prob = built[dataset]
    fitness = search.make_fitness(prob, "reference")
    n_dev = len(jax.devices())
    for s in shard_counts:
        if s > n_dev:
            print(f"ga.sharded: skipping n_shards={s} "
                  f"(host has {n_dev} devices)")
            continue
        pop = pop_per_shard * s
        cfg = nsga2.NSGA2Config(pop_size=pop, n_generations=gens)
        key = jax.random.PRNGKey(0)
        if s == 1:
            state = nsga2.init_state(key, fitness, prob.n_genes, cfg)
            chunk = jax.jit(nsga2.make_chunk(fitness, cfg, gens))
        else:
            mesh = make_search_mesh(str(s), axes=("pop",))
            state = dist.init_sharded(key, fitness, prob.n_genes, mesh, cfg)
            chunk = dist.make_sharded_chunk(fitness, mesh, cfg, gens)
        t = _timeit(chunk, state, repeat=3)
        pool = 2 * pop
        mono = pool * pool
        per_shard = mono // s
        rows.append({
            "dataset": dataset,
            "pop": pop,
            "pop_per_shard": pop_per_shard,
            "n_shards": s,
            "n_generations": gens,
            "dom_pairs_per_gen_monolithic": mono,
            "dom_pairs_per_gen_per_shard": per_shard,
            "dom_work_reduction_per_shard": mono / per_shard,
            "dispatches_per_run": 1,
            "dispatches_per_generation": 1.0 / gens,
            "us_per_generation": 1e6 * t / gens,
        })
    return rows


def write_artifact(tree_rows=None, forest_rows=None, dispatch_rows=None,
                   fitness_rows=None, sharded_rows=None, serving_rows=None,
                   mlp_fitness_rows=None, fault_rows=None,
                   path=ARTIFACT) -> str:
    """Emit BENCH_search.json: the search-engine throughput artifact.

    Sections passed as None are carried over from an existing artifact at
    ``path`` (so partial regenerations — `--fitness-only`, `--sharded-only`,
    `benchmarks/serve_bench` — don't blank the committed sections they
    didn't re-measure); absent files start every unmeasured section empty.
    Every section the artifact can hold MUST appear in the payload dict
    below: the carry-over loop iterates its keys, so a section missing here
    would be silently dropped on regeneration."""
    payload = {
        "backend": jax.default_backend(),
        "single_tree": [],
        "forest": [],
        "dispatch_per_generation": [],
        "fitness_pipeline": [],
        "sharded_search": [],
        "serving": [],
        "mlp_fitness": [],
        "fault_campaign": [],
    }
    try:
        with open(path) as f:
            prior = json.load(f)
        for k in payload:
            if k != "backend" and isinstance(prior.get(k), list):
                payload[k] = prior[k]
    except (OSError, json.JSONDecodeError, ValueError):
        pass
    for k, rows in (("single_tree", tree_rows), ("forest", forest_rows),
                    ("dispatch_per_generation", dispatch_rows),
                    ("fitness_pipeline", fitness_rows),
                    ("sharded_search", sharded_rows),
                    ("serving", serving_rows),
                    ("mlp_fitness", mlp_fitness_rows),
                    ("fault_campaign", fault_rows)):
        if rows is not None:
            payload[k] = rows
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)
    return path


def _print_fitness_rows(fitness_rows):
    for r in fitness_rows:
        print(f"ga.fitness_{r['dataset']}[{r['n_trees']}]: "
              f"seed_gen={r['us_per_generation_seed']:.1f}us "
              f"hoisted_gen={r['us_per_generation_hoisted']:.1f}us "
              f"({r['hoisted_generation_speedup']:.2f}x); kernel "
              f"scores={r['us_per_chromosome_scores_kernel']:.1f}us "
              f"fused={r['us_per_chromosome_fused_kernel']:.1f}us /chromosome; "
              f"HBM writes/eval {r['hbm_bytes_per_eval_scores']} -> "
              f"{r['hbm_bytes_per_eval_fused']} "
              f"({r['hbm_write_reduction']:.0f}x)")


def _print_mlp_rows(mlp_rows):
    for r in mlp_rows:
        print(f"ga.mlp_{r['dataset']}[h={r['n_hidden']}]: "
              f"ref={r['us_per_chromosome_ref']:.1f}us "
              f"kernel={r['us_per_chromosome_kernel']:.1f}us /chromosome "
              f"({r['kernel_speedup_vs_ref']:.2f}x); W1 stream/eval "
              f"{r['w1_stream_bytes_per_eval_ref']} -> "
              f"{r['w1_stream_bytes_per_eval_kernel']} bytes "
              f"({r['w1_stream_reduction']:.0f}x)")


def _print_sharded_rows(sharded_rows):
    for r in sharded_rows:
        print(f"ga.sharded_{r['dataset']}[S={r['n_shards']}]: "
              f"pop={r['pop']} "
              f"dom pairs/gen {r['dom_pairs_per_gen_monolithic']} -> "
              f"{r['dom_pairs_per_gen_per_shard']}/shard "
              f"({r['dom_work_reduction_per_shard']:.0f}x); "
              f"{r['dispatches_per_run']} dispatch/run, "
              f"{r['us_per_generation']:.1f}us/generation")


def main(quick=False, fitness_only=False, sharded_only=False, mlp_only=False,
         out=None):
    """``--quick`` shrinks budgets; ``--fitness-only`` / ``--sharded-only``
    / ``--mlp-only`` run just the §12 / §13 / §15 rows (the CI smoke modes)
    — with ``--out`` the artifact lands there instead of the committed
    BENCH_search.json, and any partial mode carries the unmeasured sections
    over from whatever artifact already sits at the target path."""
    path_kw = {"path": out} if out else {}
    if mlp_only:
        mlp_rows = run_mlp_fitness(
            specs=(("seeds", 4),) if quick else MLP_FITNESS_SPECS,
            pop=16 if quick else 64)
        path = write_artifact(mlp_fitness_rows=mlp_rows, **path_kw)
        _print_mlp_rows(mlp_rows)
        print(f"artifact: {path}")
        return
    if fitness_only:
        fitness_rows = run_fitness_pipeline(
            specs=(("seeds", 1), ("seeds", 2)) if quick else FITNESS_SPECS,
            pop=16 if quick else 64)
        path = write_artifact(fitness_rows=fitness_rows, **path_kw)
        _print_fitness_rows(fitness_rows)
        print(f"artifact: {path}")
        return
    if sharded_only:
        sharded_rows = run_sharded(pop_per_shard=16 if quick else 32,
                                   gens=4 if quick else 8)
        path = write_artifact(sharded_rows=sharded_rows, **path_kw)
        _print_sharded_rows(sharded_rows)
        print(f"artifact: {path}")
        return
    tree_rows = run(datasets=("seeds",) if quick else ("har", "pendigits", "seeds"),
                    pop=32 if quick else 64)
    forest_rows = run_forest(pop=32 if quick else 64)
    dispatch_rows = run_dispatch(pop=32 if quick else 64,
                                 gens=10 if quick else 20)
    fitness_rows = run_fitness_pipeline(
        specs=(("seeds", 1), ("pendigits", 1)) if quick else FITNESS_SPECS,
        pop=32 if quick else 64)
    sharded_rows = run_sharded(pop_per_shard=16 if quick else 32,
                               gens=4 if quick else 8)
    mlp_rows = run_mlp_fitness(
        specs=(("seeds", 4),) if quick else MLP_FITNESS_SPECS,
        pop=16 if quick else 64)
    path = write_artifact(tree_rows, forest_rows, dispatch_rows, fitness_rows,
                          sharded_rows, mlp_fitness_rows=mlp_rows, **path_kw)
    for r in tree_rows:
        print(f"ga.{r['dataset']}: ref={r['us_per_chromosome_ref']:.1f}us "
              f"kernel={r['us_per_chromosome_kernel']:.1f}us /chromosome")
    for r in forest_rows:
        print(f"ga.forest_{r['dataset']}[{r['n_trees']}]: "
              f"looped={r['us_per_chromosome_looped']:.1f}us "
              f"fused_ref={r['us_per_chromosome_fused_ref']:.1f}us "
              f"fused_kernel={r['us_per_chromosome_fused_kernel']:.1f}us /chromosome "
              f"(fused_ref {r['fused_ref_speedup_vs_looped']:.2f}x vs looped)")
    for r in dispatch_rows:
        print(f"ga.dispatch_{r['dataset']}: "
              f"looped={r['us_per_generation_looped']:.1f}us "
              f"chunked={r['us_per_generation_chunked']:.1f}us /generation "
              f"({r['dispatches_per_run_looped']} -> "
              f"{r['dispatches_per_run_chunked']} dispatches, "
              f"{r['chunked_speedup']:.2f}x)")
    _print_fitness_rows(fitness_rows)
    _print_sharded_rows(sharded_rows)
    _print_mlp_rows(mlp_rows)
    print(f"artifact: {path}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fitness-only", action="store_true",
                    help="only the §12 fitness_pipeline rows (CI smoke)")
    ap.add_argument("--sharded-only", action="store_true",
                    help="only the §13 sharded_search rows (CI multi-device "
                         "smoke; run under "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    ap.add_argument("--mlp-only", action="store_true",
                    help="only the §15 printed-MLP fitness rows (CI smoke)")
    ap.add_argument("--out", default=None,
                    help="artifact path (default: the committed "
                         "BENCH_search.json)")
    args = ap.parse_args()
    main(quick=args.quick, fitness_only=args.fitness_only,
         sharded_only=args.sharded_only, mlp_only=args.mlp_only,
         out=args.out)
