"""GA throughput benchmark (paper §IV: slowest single-chromosome fitness
3.08 ms on HAR). Ours is population-vectorized: we report amortized
us-per-chromosome-evaluation for the unified search engine's `reference`
(vmap) and `kernel` (fused Pallas) backends, plus one full NSGA-II
generation.

`ga.forest_*` rows compare the OLD K-iteration per-tree Python loop
(`core.forest.forest_predict`, one small program per tree) against the fused
block-diagonal super-tree evaluation (`repro.search`): reference backend =
one vote-matmul tensor program, kernel backend = ONE Pallas launch for the
entire population x test-set x forest product. The (dataset, n_trees) specs
deliberately ladder the comparator count so the fused-vs-looped crossover
(DESIGN.md §2) shows as a trend.

`ga.dispatch_*` rows measure the host-dispatch overhead the device-resident
generation loop (DESIGN.md §9) removes: N per-generation jitted dispatches
vs one `nsga2.make_chunk` lax.scan. Results are also emitted as a
BENCH_search.json artifact (see `write_artifact` / benchmarks.run).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.paper_tables import build_all
from repro.core import forest as forest_mod
from repro.core import nsga2, quant
from repro.datasets import load_dataset
from repro import search

ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "BENCH_search.json")


def _timeit(fn, *args, repeat=5):
    out = fn(*args)  # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def _looped_forest_fitness(forest, problem):
    """The historical forest fitness: a Python loop of K per-tree programs
    (gather + small matmul each), kept here as the benchmark baseline the
    fused engine is measured against."""
    x8 = problem.x8
    y = problem.y
    thresholds = jnp.concatenate(
        [jnp.asarray(p.threshold) for p in forest.ptrees])
    exact_acc = problem.exact_accuracy
    exact_area = problem.exact_area_mm2
    lut, offsets = problem.area_lut, problem.lut_offsets
    overhead = problem.overhead_mm2

    @jax.jit
    def fitness(pop):
        def one(genes):
            bits, marg = quant.decode_genes(genes)
            pred = forest_mod.forest_predict(forest, x8, bits, marg)
            acc = jnp.mean((pred == y).astype(jnp.float32))
            t_int = quant.substitute(
                quant.threshold_to_int(thresholds, bits), marg, bits)
            a = lut[offsets[bits] + t_int].sum() + overhead
            return jnp.stack([exact_acc - acc, a / exact_area])
        return jax.vmap(one)(pop)

    return fitness


def run(datasets=("har", "pendigits", "seeds"), pop=64):
    """Single-tree rows: reference vs kernel backend + one GA generation."""
    rows = []
    built = build_all(datasets)
    for name, (ds, tree, pt, prob) in built.items():
        genes = jax.random.uniform(jax.random.PRNGKey(0), (pop, prob.n_genes))
        f_ref = search.make_fitness(prob, "reference")
        t_ref = _timeit(f_ref, genes)
        f_ker = search.make_fitness(prob, "kernel")
        t_ker = _timeit(f_ker, genes)
        step = jax.jit(nsga2.make_step(
            f_ref, nsga2.NSGA2Config(pop_size=pop, n_generations=1)))
        state = nsga2.init_state(jax.random.PRNGKey(1), f_ref, prob.n_genes,
                                 nsga2.NSGA2Config(pop_size=pop))
        t_gen = _timeit(step, state)
        rows.append({
            "dataset": name,
            "n_comparators": pt.n_comparators,
            "us_per_chromosome_ref": 1e6 * t_ref / pop,
            "us_per_chromosome_kernel": 1e6 * t_ker / pop,
            "us_per_generation": 1e6 * t_gen,
            "paper_ms_per_chromosome_har": 3.08,
        })
    return rows


FOREST_SPECS = (("seeds", 4), ("vertebral", 2), ("vertebral", 4))


def run_forest(specs=FOREST_SPECS, pop=64):
    """Forest rows: looped per-tree baseline vs fused engine backends.

    The fused rows evaluate the whole forest population with NO per-tree
    Python loop — `kernel` is one Pallas program (grid = population x
    batch-blocks x leaf-blocks). `specs` is (dataset, n_trees) pairs; the
    vertebral[2] row sits between the seeds[4] and vertebral[4] comparator
    counts so the fused-vs-looped crossover (DESIGN.md §2) is visible as a
    trend, not a cliff."""
    rows = []
    for name, n_trees in specs:
        ds = load_dataset(name)
        forest = forest_mod.train_forest(ds.x_train, ds.y_train, ds.n_classes,
                                         n_trees=n_trees)
        prob = search.build_forest_problem(forest, ds.x_test, ds.y_test)
        genes = jax.random.uniform(jax.random.PRNGKey(0), (pop, prob.n_genes))
        f_loop = _looped_forest_fitness(forest, prob)
        f_ref = search.make_fitness(prob, "reference")
        f_ker = search.make_fitness(prob, "kernel")
        t_loop = _timeit(f_loop, genes)
        t_ref = _timeit(f_ref, genes)
        t_ker = _timeit(f_ker, genes)
        rows.append({
            "dataset": name,
            "n_trees": n_trees,
            "n_comparators": prob.n_comparators,
            "us_per_chromosome_looped": 1e6 * t_loop / pop,
            "us_per_chromosome_fused_ref": 1e6 * t_ref / pop,
            "us_per_chromosome_fused_kernel": 1e6 * t_ker / pop,
            "fused_ref_speedup_vs_looped": t_loop / t_ref,
        })
    return rows


def run_dispatch(datasets=("seeds",), pop=64, gens=20):
    """Host-dispatch overhead rows (DESIGN.md §9): one jitted step per
    generation (the pre-§9 driver, `gens` host round-trips) vs ONE
    `nsga2.make_chunk` lax.scan for the whole run (a single dispatch).
    The arithmetic is identical — the gap is pure dispatch overhead."""
    rows = []
    built = build_all(datasets)
    for name, (ds, tree, pt, prob) in built.items():
        f_ref = search.make_fitness(prob, "reference")
        cfg = nsga2.NSGA2Config(pop_size=pop, n_generations=gens)
        state = nsga2.init_state(jax.random.PRNGKey(0), f_ref, prob.n_genes,
                                 cfg)
        step = jax.jit(nsga2.make_step(f_ref, cfg))

        def looped(s):
            for _ in range(gens):
                s = step(s)
            return s

        chunk = jax.jit(nsga2.make_chunk(f_ref, cfg, gens))
        t_loop = _timeit(looped, state)
        t_chunk = _timeit(chunk, state)
        rows.append({
            "dataset": name,
            "pop": pop,
            "n_generations": gens,
            "dispatches_per_run_looped": gens,
            "dispatches_per_run_chunked": 1,
            "us_per_generation_looped": 1e6 * t_loop / gens,
            "us_per_generation_chunked": 1e6 * t_chunk / gens,
            "dispatch_overhead_us_per_generation": 1e6 * (t_loop - t_chunk) / gens,
            "chunked_speedup": t_loop / t_chunk,
        })
    return rows


def write_artifact(tree_rows, forest_rows, dispatch_rows=None,
                   path=ARTIFACT) -> str:
    """Emit BENCH_search.json: the search-engine throughput artifact."""
    payload = {
        "backend": jax.default_backend(),
        "single_tree": tree_rows,
        "forest": forest_rows,
        "dispatch_per_generation": dispatch_rows or [],
    }
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)
    return path


def main(quick=False):
    tree_rows = run(datasets=("seeds",) if quick else ("har", "pendigits", "seeds"),
                    pop=32 if quick else 64)
    forest_rows = run_forest(pop=32 if quick else 64)
    dispatch_rows = run_dispatch(pop=32 if quick else 64,
                                 gens=10 if quick else 20)
    path = write_artifact(tree_rows, forest_rows, dispatch_rows)
    for r in tree_rows:
        print(f"ga.{r['dataset']}: ref={r['us_per_chromosome_ref']:.1f}us "
              f"kernel={r['us_per_chromosome_kernel']:.1f}us /chromosome")
    for r in forest_rows:
        print(f"ga.forest_{r['dataset']}[{r['n_trees']}]: "
              f"looped={r['us_per_chromosome_looped']:.1f}us "
              f"fused_ref={r['us_per_chromosome_fused_ref']:.1f}us "
              f"fused_kernel={r['us_per_chromosome_fused_kernel']:.1f}us /chromosome "
              f"(fused_ref {r['fused_ref_speedup_vs_looped']:.2f}x vs looped)")
    for r in dispatch_rows:
        print(f"ga.dispatch_{r['dataset']}: "
              f"looped={r['us_per_generation_looped']:.1f}us "
              f"chunked={r['us_per_generation_chunked']:.1f}us /generation "
              f"({r['dispatches_per_run_looped']} -> "
              f"{r['dispatches_per_run_chunked']} dispatches, "
              f"{r['chunked_speedup']:.2f}x)")
    print(f"artifact: {path}")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
