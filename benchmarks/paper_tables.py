"""Paper-table reproductions (one function per paper table/figure).

table1 — exact bespoke DT per dataset (paper Table I)
table2 — approximate designs at the 1% accuracy-loss threshold (paper Table II)
fig4   — comparator area vs threshold at 6/8 bits (paper Fig. 4)
fig5   — pareto fronts: estimated (additive LUT, the GA's oracle) vs actual
         (CSE/dedup synthesis model) per dataset (paper Fig. 5)

Results are cached as JSON under benchmarks/results/paper/ so re-runs are
incremental. All areas in mm^2, power in mW (EGT calibration, DESIGN.md §4).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.datasets import DATASET_SPECS, load_dataset
# Paper reference numbers live in the package so `repro.search sweep --report`
# can score runs without benchmarks/ on sys.path; re-exported here for
# historical call sites.
from repro.datasets.paper_refs import PAPER_TABLE1, PAPER_TABLE2_NORM
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.core import approx, area, nsga2, quant

RESULTS = os.path.join(os.path.dirname(__file__), "results", "paper")


def _cache(name: str):
    os.makedirs(RESULTS, exist_ok=True)
    return os.path.join(RESULTS, name + ".json")


def build_all(datasets=None):
    """Train every exact bespoke tree; returns {name: (ds, tree, ptree, prob)}."""
    out = {}
    for name in (datasets or DATASET_SPECS):
        ds = load_dataset(name)
        tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
        pt = to_parallel(tree)
        prob = approx.build_problem(pt, ds.x_test, ds.y_test)
        out[name] = (ds, tree, pt, prob)
    return out


def exact_metrics(pt, prob) -> dict:
    t8 = np.clip(np.floor(pt.threshold * 256).astype(np.int64), 0, 255)
    bits = np.full(pt.n_comparators, 8)
    a_ded = area.tree_area_mm2(pt.feature, t8, bits, pt.n_leaves, dedup=True)
    a_add = area.tree_area_mm2(pt.feature, t8, bits, pt.n_leaves, dedup=False)
    return {
        "accuracy": prob.exact_accuracy,
        "n_comparators": pt.n_comparators,
        "delay_ms": area.delay_ms(pt.n_comparators),
        "area_mm2": a_ded,
        "area_estimate_mm2": a_add,
        "power_mw": area.power_mw(a_ded),
    }


def table1(built=None, force=False) -> dict:
    path = _cache("table1")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    built = built or build_all()
    rows = {}
    for name, (ds, tree, pt, prob) in built.items():
        rows[name] = exact_metrics(pt, prob)
        rows[name]["paper"] = dict(zip(
            ("accuracy", "n_comparators", "delay_ms", "area_mm2", "power_mw"),
            PAPER_TABLE1[name]))
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def run_search(name, pt, prob, pop=64, gens=40, seed=0, use_kernel=False,
               n_features=None):
    """One dataset's NSGA-II search through the unified engine."""
    from repro import search
    result = search.run_search(
        prob, backend="kernel" if use_kernel else "reference",
        pop_size=pop, n_generations=gens, seed=seed)
    return result.pareto_objs, result.pareto_genes


def actual_area_mm2(pt, genes) -> float:
    """Dedup (synthesis) area for one chromosome — the 'actual' oracle.

    Truncation (DESIGN.md §16) folds into effective precision/threshold
    before pricing: a k-LSB-truncated p-bit comparator IS a (p-k)-bit one."""
    bits, margin, trunc, _vote = quant.decode_tree_genes(jnp.asarray(genes))
    t_sub = quant.substitute(
        quant.threshold_to_int(jnp.asarray(pt.threshold), bits), margin, bits)
    bits_eff = np.asarray(bits - trunc)
    t_eff = np.asarray(jnp.right_shift(t_sub, trunc))
    return area.tree_area_mm2(pt.feature, t_eff, bits_eff,
                              pt.n_leaves, dedup=True)


def fig5_and_table2(pop=64, gens=40, force=False, datasets=None) -> dict:
    """NSGA-II over the whole suite; pareto fronts (estimated + actual) and
    the 1%/2% loss threshold summaries.

    Since DESIGN.md §11 this runs as ONE batched campaign through
    `repro.search.sweep` — problems padded to bucket boundaries and advanced
    with one vmapped dispatch per bucket per stage — instead of the
    historical per-dataset `run_search` loop (kept available as
    `run_search` above for one-off single-dataset studies)."""
    from repro.search import sweep as sweep_mod

    path = _cache(f"fig5_pop{pop}_gens{gens}")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    built = build_all(datasets)
    sweep = sweep_mod.run_sweep({name: prob
                                 for name, (ds, tree, pt, prob) in built.items()},
                                pop_size=pop, n_generations=gens)
    out = {}
    for name, (ds, tree, pt, prob) in built.items():
        t0 = time.time()
        result = sweep.results[name]
        objs, genes = result.pareto_objs, result.pareto_genes
        exact = exact_metrics(pt, prob)
        pts = []
        for o, g in zip(objs, genes):
            a_act = actual_area_mm2(pt, g)
            pts.append({
                "acc_loss": float(o[0]),
                "norm_area_est": float(o[1]),
                "area_actual_mm2": float(a_act),
                "norm_area_actual": float(a_act / exact["area_mm2"]),
            })
        def best_at(thr):
            ok = [p for p in pts if p["acc_loss"] <= thr + 1e-9]
            if not ok:
                return None
            b = min(ok, key=lambda p: p["norm_area_actual"])
            return {
                "norm_area": b["norm_area_actual"],
                "norm_power": b["norm_area_actual"],  # power tracks area
                "area_mm2": b["area_actual_mm2"],
                "power_mw": area.power_mw(b["area_actual_mm2"]),
                "accuracy": exact["accuracy"] - b["acc_loss"],
            }
        out[name] = {
            "exact": exact,
            "pareto": pts,
            "at_1pct": best_at(0.01),
            "at_2pct": best_at(0.02),
            "paper_at_1pct": dict(zip(("norm_area", "norm_power"),
                                      PAPER_TABLE2_NORM[name])),
            # SHARED by every dataset in the same sweep bucket — sum the
            # campaign row below, not these, for suite totals
            "bucket_search_s": round(result.wall_s, 1),
            "bucket_dispatches": result.n_dispatches,
            "postprocess_s": round(time.time() - t0, 1),
        }
    # campaign-level accounting (the only summable wall/dispatch numbers)
    out["_sweep"] = {
        "wall_s": round(sweep.wall_s, 1),
        "n_dispatches": sweep.n_dispatches,
        "serial_baseline_dispatches": sweep.serial_baseline_dispatches(),
        "n_buckets": len(sweep.bucket_runs),
    }
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def fig4() -> dict:
    out = {}
    for p in (6, 8):
        out[str(p)] = [area.comparator_area_mm2(t, p) for t in range(1 << p)]
    return out


def summarize(results: dict) -> dict:
    """Cross-dataset means (paper: 3.2x area / 3.4x power at 1% loss)."""
    red_a, red_p = [], []
    for name, r in results.items():
        if name.startswith("_"):  # the campaign-accounting row, not a dataset
            continue
        if r.get("at_1pct"):
            red_a.append(1.0 / r["at_1pct"]["norm_area"])
            red_p.append(1.0 / r["at_1pct"]["norm_power"])
    return {
        "mean_area_reduction_1pct": float(np.mean(red_a)) if red_a else None,
        "mean_power_reduction_1pct": float(np.mean(red_p)) if red_p else None,
        "n_datasets": len(red_a),
        "paper_area_reduction": 3.2,
        "paper_power_reduction": 3.4,
    }
