"""Fault-campaign microbenchmark: vmapped stuck-at lanes vs the serial oracle.

Measures `core.faults.FaultSimulator` (DESIGN.md §17) on exact tree and
forest designs: the exhaustive single stuck-at campaign (every fault site x
2 polarities x the full test split) through the chunked vmapped program,
against `simulate_faulty_serial` — the per-gate Python oracle — on a fixed
subset of the same lanes (the serial loop is deliberately naive; timing it
on every lane would dominate the bench).

Each `fault_campaign` row in BENCH_search.json records site throughput for
both paths plus three deterministic invariants floor-checked by
`tools/check_bench.py` (CI `--smoke` included):

  - `zero_fault_mismatches == 0`: the empty-mask lane is bit-identical to
    `core.netlist.simulate` over the full test split;
  - `single_fault_oracle_mismatches == 0`: every sampled vmapped lane
    matches the serial oracle array-for-array;
  - `n_faults == 2 * n_sites`: stuck-at-0 AND stuck-at-1 of every site.

The specs stay in the paper's printed-circuit regime (tens to ~a thousand
gates, small tabular test splits) — that is where the vmapped-beats-serial
floor holds and where every artifact's designs live. Far outside it
(thousands of gates x thousands of vectors, e.g. an exact pendigits tree)
the per-level value-table traffic of the levelized evaluator dominates and
the naive per-gate numpy loop wins; the campaign layer still works there,
it is just not what this bench floors.

Run:  PYTHONPATH=src python -m benchmarks.fault_bench [--quick] [--out P]
(with --out the artifact lands there instead of the committed
BENCH_search.json; unmeasured sections carry over either way).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.ga_bench import write_artifact
from repro import search
from repro.core import faults, netlist
from repro.core.forest import train_forest
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.datasets import load_dataset, quantize_u8

# (dataset, n_trees): exact designs, the largest circuits the artifact's
# pareto points shrink from — single tree, wide forest, widest forest
FAULT_SPECS = (("seeds", 1), ("vertebral", 3), ("seeds", 4))
QUICK_SPECS = (("seeds", 1),)

N_ORACLE_LANES = 8   # serial-oracle comparison subset (evenly spaced)


def _build_circuit(dataset: str, n_trees: int):
    import jax.numpy as jnp

    ds = load_dataset(dataset)
    if n_trees <= 1:
        pt = to_parallel(train_tree(ds.x_train, ds.y_train, ds.n_classes))
        problem = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    else:
        forest = train_forest(ds.x_train, ds.y_train, ds.n_classes,
                              n_trees=n_trees)
        problem = search.build_forest_problem(forest, ds.x_test, ds.y_test)
    bits, t_int, _ = search.decode_chromosome(
        problem, jnp.asarray(problem.exact_genes()))
    circuit = netlist.build_circuit(search.problem_ptrees(problem),
                                    np.asarray(bits), np.asarray(t_int),
                                    problem.n_classes)
    x8 = quantize_u8(ds.x_test)
    return circuit, x8


def run_fault_campaign(specs=FAULT_SPECS) -> list[dict]:
    rows = []
    for dataset, n_trees in specs:
        circuit, x8 = _build_circuit(dataset, n_trees)
        sim = faults.FaultSimulator(circuit)
        sites = faults.enumerate_fault_sites(circuit)
        gates, values = faults.single_fault_lanes(circuit, sites)
        n_faults = len(gates)

        # deterministic invariant 1: the empty mask is the plain simulator
        zero = sim.run_zero_fault(x8)
        oracle = np.asarray(netlist.simulate(circuit, x8))
        zero_mismatches = int((zero != oracle).sum())

        # vmapped exhaustive campaign: one full warm pass compiles the
        # chunk-shaped program, the second pass is the steady-state timing
        sim.run_sites(x8, gates, values)
        t0 = time.perf_counter()
        preds = sim.run_sites(x8, gates, values)
        wall_vmapped = time.perf_counter() - t0

        # deterministic invariant 2: sampled lanes vs the serial oracle
        lanes = np.linspace(0, n_faults - 1, min(N_ORACLE_LANES, n_faults),
                            dtype=np.int64)
        mismatches = 0
        t0 = time.perf_counter()
        for i in lanes:
            serial = faults.simulate_faulty_serial(
                circuit, x8, [(gates[i], values[i])])
            mismatches += int(not np.array_equal(preds[i], serial))
        wall_serial = time.perf_counter() - t0

        faults_per_s = n_faults / max(wall_vmapped, 1e-9)
        serial_per_s = len(lanes) / max(wall_serial, 1e-9)
        rows.append({
            "dataset": dataset,
            "n_trees": n_trees,
            "n_gates": int(circuit.n_gates),
            "n_sites": len(sites),
            "n_faults": int(n_faults),
            "n_samples": int(x8.shape[0]),
            "chunk": faults.auto_chunk(circuit, int(x8.shape[0])),
            "faults_per_s_vmapped": round(faults_per_s, 1),
            "faults_per_s_serial": round(serial_per_s, 1),
            "vmapped_speedup_vs_serial":
                round(faults_per_s / max(serial_per_s, 1e-9), 2),
            "zero_fault_mismatches": zero_mismatches,
            "single_fault_oracle_mismatches": mismatches,
            "n_oracle_lanes": int(len(lanes)),
        })
    return rows


def _print_rows(rows):
    for r in rows:
        print(f"faults.{r['dataset']}[{r['n_trees']}]: {r['n_gates']} gates, "
              f"{r['n_sites']} sites x 2 = {r['n_faults']} faults over "
              f"{r['n_samples']} vectors (chunk {r['chunk']}): "
              f"vmapped {r['faults_per_s_vmapped']:,.0f} faults/s vs serial "
              f"{r['faults_per_s_serial']:,.1f} "
              f"({r['vmapped_speedup_vs_serial']}x; "
              f"zero_fault_mismatches={r['zero_fault_mismatches']} "
              f"oracle_mismatches={r['single_fault_oracle_mismatches']})")


def main(quick=False, out=None):
    rows = run_fault_campaign(QUICK_SPECS if quick else FAULT_SPECS)
    path = write_artifact(fault_rows=rows, **({"path": out} if out else {}))
    _print_rows(rows)
    print(f"artifact: {path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="one dataset (CI smoke)")
    ap.add_argument("--out", default=None,
                    help="artifact path (default: committed BENCH_search.json)")
    args = ap.parse_args()
    main(quick=args.quick, out=args.out)
