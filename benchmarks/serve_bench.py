"""Serving-runtime microbenchmark: the featurize → batch → classify split.

Measures `runtime.classify.ClassifyServer` (DESIGN.md §14) the way an LM
serving bench splits prefill/insert/generate — one stage at a time, so a
regression points at the stage that caused it:

  - **featurize**: float features -> master 8-bit codes (host quantize);
  - **batch**: request codes -> padded power-of-two bucket (host pad);
  - **classify**: one resident ping-pong step through the fused inference
    kernel, including the cropped readback of the real rows.

Each `serving` row in BENCH_search.json records the per-stage and total
per-request latencies, throughput, the per-sample speedup of batched
serving over batch=1 dispatches, and two deterministic zero-cost
invariants floor-checked by `tools/check_bench.py` (CI `--smoke` included):

  - `steady_state_new_arrays == 0`: after the ping-pong slots warm up,
    serving K more steps must not grow `jax.live_arrays()` — the donated
    two-slot state recycles its buffers instead of reallocating;
  - `compiles_after_warmup == 0`: every request size inside a bucket reuses
    the bucket's compiled step — steady-state serving never re-traces.

Run:  PYTHONPATH=src python -m benchmarks.serve_bench [--quick] [--out P]
(with --out the artifact lands there instead of the committed
BENCH_search.json; unmeasured sections carry over either way).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.ga_bench import write_artifact
from repro import search
from repro.datasets import load_dataset
from repro.core.forest import train_forest
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.runtime.classify import ClassifyServer

# (dataset, n_trees, request sizes). batch=1 anchors the batched-speedup
# ratio; >= 32 rows are the ones check_bench floors (batch=1 dispatch
# overhead is exactly what batching amortizes away).
SERVE_SPECS = (
    ("seeds", 1, (1, 16, 64, 256)),
    ("pendigits", 1, (1, 64, 256)),
    ("seeds", 4, (1, 64)),
)
QUICK_SPECS = (("seeds", 1, (1, 64)),)

WARMUP_STEPS = 4          # >= 2 fills both ping-pong slots per bucket
STEADY_STEPS = 16


def _time_stage(fn, repeat: int) -> float:
    """Best-of per-call seconds over `repeat`-sized batches (3 trials)."""
    fn()  # warm (compile/allocate)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(repeat):
            fn()
        best = min(best, (time.perf_counter() - t0) / repeat)
    return best


def _build_server(dataset: str, n_trees: int) -> tuple:
    ds = load_dataset(dataset)
    if n_trees <= 1:
        pt = to_parallel(train_tree(ds.x_train, ds.y_train, ds.n_classes))
        problem = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    else:
        forest = train_forest(ds.x_train, ds.y_train, ds.n_classes,
                              n_trees=n_trees)
        problem = search.build_forest_problem(forest, ds.x_test, ds.y_test)
    # the exact (8-bit, zero-margin) design: the serving payload every
    # searched point is a shrunken version of
    import jax.numpy as jnp
    bits, t_int, _vote_cap = search.decode_chromosome(
        problem, jnp.asarray(problem.exact_genes()))
    server = ClassifyServer(search.problem_ptrees(problem),
                            np.asarray(bits), np.asarray(t_int),
                            problem.n_classes, problem.n_features)
    return server, problem, ds


def _request_pool(ds, batch: int) -> list[np.ndarray]:
    """Two distinct request payloads (float features) of `batch` rows —
    alternating them keeps the steady-state loop from serving one constant
    buffer the runtime could cache."""
    x = np.asarray(ds.x_test, np.float32)
    reps = -(-2 * batch // x.shape[0])
    pool = np.tile(x, (max(1, reps), 1))[: 2 * batch]
    if pool.shape[0] < 2 * batch:  # tiny split: repeat rows
        pool = np.tile(pool, (-(-2 * batch // pool.shape[0]), 1))[: 2 * batch]
    return [pool[:batch], pool[batch: 2 * batch]]


def run_serving(specs=SERVE_SPECS) -> list[dict]:
    rows = []
    for dataset, n_trees, batches in specs:
        server, problem, ds = _build_server(dataset, n_trees)
        per_sample_b1 = None
        for batch in batches:
            reqs = _request_pool(ds, batch)
            codes = [server.featurize(r) for r in reqs]
            padded = [server.batch(c)[0][0] for c in codes]
            bucket = padded[0].shape[0]
            n_real = batch

            # warm both ping-pong slots + the bucket's compiled step
            for i in range(WARMUP_STEPS):
                np.asarray(server.step(padded[i % 2]))[:n_real]

            # deterministic steady-state invariants
            compiles0 = server.compile_count()
            live0 = len(jax.live_arrays())
            for i in range(STEADY_STEPS):
                np.asarray(server.step(padded[i % 2]))[:n_real]
            new_arrays = max(0, len(jax.live_arrays()) - live0)
            new_compiles = server.compile_count() - compiles0

            # per-stage timings (amortize to >= ~30ms batches of calls)
            i_box = [0]

            def classify_once():
                i_box[0] ^= 1
                return np.asarray(server.step(padded[i_box[0]]))[:n_real]

            s_feat = _time_stage(lambda: server.featurize(reqs[0]),
                                 repeat=max(20, 2000 // max(batch, 1)))
            s_batch = _time_stage(lambda: server.batch(codes[0]),
                                  repeat=max(20, 2000 // max(batch, 1)))
            s_cls = _time_stage(classify_once, repeat=50)
            us_total = (s_feat + s_batch + s_cls) * 1e6
            per_sample = us_total / batch
            if batch == 1:
                per_sample_b1 = per_sample
            speedup = (per_sample_b1 / per_sample
                       if per_sample_b1 is not None else 1.0)
            rows.append({
                "dataset": dataset,
                "n_trees": n_trees,
                "n_comparators": problem.n_comparators,
                "n_classes": problem.n_classes,
                "batch": batch,
                "bucket": bucket,
                "us_featurize_per_req": round(s_feat * 1e6, 2),
                "us_batch_per_req": round(s_batch * 1e6, 2),
                "us_classify_per_req": round(s_cls * 1e6, 2),
                "us_total_per_req": round(us_total, 2),
                "requests_per_s": round(1e6 / max(us_total, 1e-9), 1),
                "samples_per_s": round(batch * 1e6 / max(us_total, 1e-9), 1),
                "batched_speedup_vs_b1": round(speedup, 3),
                "steady_state_new_arrays": int(new_arrays),
                "compiles_after_warmup": int(new_compiles),
                "n_steps": int(server.stats.n_steps),
            })
    return rows


def _print_rows(rows):
    for r in rows:
        print(f"serve.{r['dataset']}[{r['n_trees']}] b={r['batch']}"
              f"->bucket {r['bucket']}: "
              f"featurize={r['us_featurize_per_req']}us "
              f"batch={r['us_batch_per_req']}us "
              f"classify={r['us_classify_per_req']}us "
              f"({r['samples_per_s']:,.0f} samples/s, "
              f"{r['batched_speedup_vs_b1']}x vs b=1/sample; "
              f"new_arrays={r['steady_state_new_arrays']} "
              f"recompiles={r['compiles_after_warmup']})")


def main(quick=False, out=None):
    rows = run_serving(QUICK_SPECS if quick else SERVE_SPECS)
    path = write_artifact(serving_rows=rows,
                          **({"path": out} if out else {}))
    _print_rows(rows)
    print(f"artifact: {path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="one dataset, two request sizes (CI smoke)")
    ap.add_argument("--out", default=None,
                    help="artifact path (default: committed BENCH_search.json)")
    args = ap.parse_args()
    main(quick=args.quick, out=args.out)
