"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Heavy GA searches are cached
under benchmarks/results/paper/. Roofline rows are derived from the dry-run
artifacts if present (run ``python -m repro.launch.dryrun`` first for those).
"""
from __future__ import annotations

import argparse


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller GA budgets (smoke use)")
    ap.add_argument("--pop", type=int, default=None)
    ap.add_argument("--gens", type=int, default=None)
    args = ap.parse_args()
    pop = args.pop or (32 if args.quick else 64)
    gens = args.gens or (12 if args.quick else 40)

    from benchmarks import ga_bench, kernel_bench, paper_tables, roofline

    print("name,us_per_call,derived")

    # ---- Table I: exact bespoke DTs --------------------------------------
    t1 = paper_tables.table1()
    for name, r in t1.items():
        _row(f"table1.{name}", 0.0,
             f"acc={r['accuracy']:.3f};comps={r['n_comparators']};"
             f"area_mm2={r['area_mm2']:.1f};power_mw={r['power_mw']:.2f};"
             f"paper_area={r['paper']['area_mm2']}")

    # ---- Fig. 4: comparator area LUT -------------------------------------
    f4 = paper_tables.fig4()
    import numpy as np
    for p, vals in f4.items():
        _row(f"fig4.p{p}", 0.0,
             f"mean_mm2={np.mean(vals):.3f};zero_area_frac="
             f"{np.mean(np.array(vals) == 0):.3f}")

    # ---- Fig. 5 + Table II: NSGA-II pareto fronts ------------------------
    f5 = paper_tables.fig5_and_table2(pop=pop, gens=gens)
    for name, r in f5.items():
        a1 = r["at_1pct"]
        derived = (f"pareto_n={len(r['pareto'])};search_s={r['search_s']}")
        if a1:
            derived += (f";area_red_1pct={1/a1['norm_area']:.2f}x"
                        f";power_mw={a1['power_mw']:.2f}"
                        f";paper_norm_area={r['paper_at_1pct']['norm_area']}")
        _row(f"fig5.{name}", r["search_s"] * 1e6, derived)
    summary = paper_tables.summarize(f5)
    _row("table2.summary", 0.0,
         f"mean_area_red={summary['mean_area_reduction_1pct']:.2f}x"
         f";mean_power_red={summary['mean_power_reduction_1pct']:.2f}x"
         f";paper=3.2x/3.4x")

    # ---- GA throughput (paper §IV time-complexity claim) -----------------
    ga_rows = ga_bench.run()
    for r in ga_rows:
        _row(f"ga.{r['dataset']}", r["us_per_chromosome_ref"],
             f"kernel_us={r['us_per_chromosome_kernel']:.1f};"
             f"gen_us={r['us_per_generation']:.0f};"
             f"paper_har_ms=3.08")

    # ---- forest GA: looped per-tree baseline vs fused search engine ------
    forest_rows = ga_bench.run_forest(pop=pop)
    for r in forest_rows:
        _row(f"ga.forest_{r['dataset']}[{r['n_trees']}]",
             r["us_per_chromosome_fused_ref"],
             f"looped_us={r['us_per_chromosome_looped']:.1f};"
             f"fused_kernel_us={r['us_per_chromosome_fused_kernel']:.1f};"
             f"n_trees={r['n_trees']};"
             f"fused_speedup={r['fused_ref_speedup_vs_looped']:.2f}x")

    # ---- host-dispatch overhead: per-generation loop vs chunked scan -----
    dispatch_rows = ga_bench.run_dispatch(pop=pop, gens=min(gens, 20))
    for r in dispatch_rows:
        _row(f"ga.dispatch_{r['dataset']}", r["us_per_generation_looped"],
             f"chunked_us={r['us_per_generation_chunked']:.1f};"
             f"dispatches={r['dispatches_per_run_looped']}->"
             f"{r['dispatches_per_run_chunked']};"
             f"speedup={r['chunked_speedup']:.2f}x")
    # ---- fused fitness pipeline (DESIGN.md §12) --------------------------
    fitness_rows = ga_bench.run_fitness_pipeline(pop=pop)
    for r in fitness_rows:
        _row(f"ga.fitness_{r['dataset']}[{r['n_trees']}]",
             r["us_per_generation_hoisted"],
             f"seed_gen_us={r['us_per_generation_seed']:.1f};"
             f"hoisted_speedup={r['hoisted_generation_speedup']:.2f}x;"
             f"hbm_write_reduction={r['hbm_write_reduction']:.0f}x")
    artifact = ga_bench.write_artifact(ga_rows, forest_rows, dispatch_rows,
                                       fitness_rows)
    _row("ga.artifact", 0.0, f"path={artifact}")

    # ---- kernel microbenches ---------------------------------------------
    for r in kernel_bench.run():
        _row(f"kernel.{r['kernel']}", r["us_interpret"],
             f"ref_us={r['us_ref_jnp']:.1f};gflops={r['gflops_at_ref']:.1f}")

    # ---- roofline (from dry-run artifacts, if present) --------------------
    for mesh in ("pod16x16", "pod2x16x16"):
        try:
            rows = roofline.load_all(mesh)
        except Exception:
            rows = []
        for r in rows:
            if "t_compute_s" in r:
                _row(f"roofline.{mesh}.{r['arch']}.{r['shape']}",
                     r["t_compute_s"] * 1e6,
                     f"mem_s={r['t_memory_s']:.3f};coll_s={r['t_collective_s']:.3f};"
                     f"dominant={r['dominant']};frac={r['roofline_fraction']:.2f}")


if __name__ == "__main__":
    main()
