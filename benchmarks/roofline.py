"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs / peak_FLOPs                [s]
  memory term     = HLO_bytes_accessed / HBM_bw           [s]
  collective term = collective_wire_bytes / ICI_bw        [s]

cost_analysis() on the SPMD-partitioned module is per-device, so terms use
single-chip peaks. Collective wire bytes weight each op kind by its byte
multiplier on the link (all-reduce moves ~2x its payload: RS+AG).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for train; 2*N*D for
inference shapes. The ratio MODEL_FLOPS / HLO_FLOPs measures how much of the
compiled compute is "useful" (catches remat/dispatch overheads).

v5e chip constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(we count 1 link per direction as the conservative bisection).
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

# XLA:CPU has no native bf16: the float-normalization pass upcasts every
# bf16 buffer to f32 in the partitioned HLO (verified: bf16 weights appear
# as f32 in collective payloads). On TPU those buffers move at bf16 width,
# so byte-based terms are scaled by ~0.5 (true-f32 residue — optimizer
# moments, softmax stats — keeps this a slight underestimate; +/-10%).
BF16_NORMALIZATION_CORRECTION = 0.5

# wire-byte multiplier per collective kind (ring algorithms, large-group limit)
WIRE_MULT = {"all-gather": 1.0, "reduce-scatter": 1.0, "all-reduce": 2.0,
             "all-to-all": 1.0, "collective-permute": 1.0}

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def model_flops(cfg, shape_cfg) -> float:
    n_active = cfg.n_params_compute_estimate
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n_active * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention reads over the cache
    tokens = shape_cfg.global_batch
    return 2.0 * n_active * tokens


def wire_bytes(collectives: dict) -> float:
    total = 0.0
    for kind, mult in WIRE_MULT.items():
        total += collectives.get(kind, {}).get("bytes", 0) * mult
    return total


def analyze_record(rec: dict) -> dict:
    from repro.configs import get_config, SHAPES
    cfg = get_config(rec["arch"])
    shape_cfg = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]
    if "loop_aware" in rec:  # trip-count-corrected (hlo_analysis)
        flops_dev = rec["loop_aware"]["flops"]
        bytes_dev = rec["loop_aware"]["bytes"] * BF16_NORMALIZATION_CORRECTION
        coll_dev = wire_bytes(rec["loop_aware"]["collectives"]) \
            * BF16_NORMALIZATION_CORRECTION
    else:  # legacy records: while bodies counted once (under-estimates)
        flops_dev = rec["cost"]["flops"]
        bytes_dev = rec["cost"]["bytes_accessed"]
        coll_dev = wire_bytes(rec["collectives"])
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    mf = model_flops(cfg, shape_cfg)
    useful = mf / (flops_dev * n_dev) if flops_dev > 0 else 0.0
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    bound = max(t_compute, t_memory, t_coll)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": flops_dev * n_dev,
        "useful_flops_ratio": useful,
        "roofline_fraction": (t_compute / bound) if bound > 0 else 0.0,
        "mem_gib_per_dev": (rec["memory"]["argument_bytes"]
                            + rec["memory"]["temp_bytes"]) / 2**30,
        "status": rec["status"],
    }


def load_all(mesh: str = "pod16x16") -> list[dict]:
    from repro.configs import ARCH_IDS
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("arch") not in ARCH_IDS:
            continue  # auxiliary cells (paper-dt-ga) have their own report
        if rec.get("status") == "ok":
            out.append(analyze_record(rec))
        else:
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "status": rec.get("status"),
                        "error": rec.get("error", "")[:120]})
    return out


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful FLOPs | roofline frac | mem GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("status") != "ok" and "t_compute_s" not in r:
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL: "
                         f"{r.get('error','')} | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['mem_gib_per_dev']:.1f} |")
    return "\n".join(lines)
