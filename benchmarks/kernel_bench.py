"""Pallas kernel microbenches.

On this CPU container kernels execute through the interpreter, so absolute
numbers are NOT TPU numbers — we report them for regression tracking plus
the jnp-reference time for the same math (the kernels' oracle cost)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _timeit(fn, *args, repeat=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def run():
    rows = []
    rng = np.random.default_rng(0)

    # qmatmul: the LM-side mixed-precision matmul
    x = jnp.asarray(rng.normal(size=(256, 1024)).astype(np.float32))
    w = jnp.asarray(rng.integers(-8, 8, (1024, 512)).astype(np.int8))
    s = jnp.asarray(rng.uniform(0.01, 0.1, (512,)).astype(np.float32))
    t_int = _timeit(lambda: ops.qmatmul(x, w, s, interpret=True))
    t_ref = _timeit(lambda: ref.qmatmul(x, w, s.reshape(1, -1)))
    flops = 2 * 256 * 1024 * 512
    rows.append({"kernel": "qmatmul_256x1024x512",
                 "us_interpret": 1e6 * t_int, "us_ref_jnp": 1e6 * t_ref,
                 "gflops_at_ref": flops / t_ref / 1e9})

    # domination: NSGA-II O(P^2)
    objs = jnp.asarray(rng.uniform(0, 1, (512, 2)).astype(np.float32))
    t_int = _timeit(lambda: ops.domination_matrix(objs, interpret=True))
    t_ref = _timeit(lambda: ref.domination_matrix(objs))
    rows.append({"kernel": "domination_512", "us_interpret": 1e6 * t_int,
                 "us_ref_jnp": 1e6 * t_ref,
                 "gflops_at_ref": 512 * 512 * 6 / t_ref / 1e9})

    return rows
