"""Spec invariants for all 10 synthetic UCI stand-ins (repro.datasets).

The sweep engine (DESIGN.md §11) runs every dataset in one campaign, so every
spec entry — not just the two CI historically touched — must uphold the
contract the search stack assumes: spec-matching shapes, in-range labels,
integer-level grids, determinism, and train-statistic-only normalization.
"""
import numpy as np
import pytest

from repro.datasets import DATASET_SPECS, load_dataset, quantize_u8
from repro.datasets.synthetic import _generate, _normalize01, train_test_split

ALL_NAMES = sorted(DATASET_SPECS)


def test_suite_is_the_papers_ten():
    assert ALL_NAMES == ["arrhythmia", "balance", "cardio", "har",
                         "mammographic", "pendigits", "redwine", "seeds",
                         "vertebral", "whitewine"]


@pytest.mark.parametrize("name", ALL_NAMES)
def test_spec_shapes_and_labels(name):
    spec = DATASET_SPECS[name]
    ds = load_dataset(name)
    n_train, n_test = ds.x_train.shape[0], ds.x_test.shape[0]
    assert n_train + n_test == spec.n_samples
    assert n_test == int(round(spec.n_samples * 0.3))  # paper's 30% split
    assert ds.x_train.shape[1] == ds.x_test.shape[1] == spec.n_features
    assert ds.n_classes == spec.n_classes
    for y in (ds.y_train, ds.y_test):
        assert y.dtype == np.int32
        assert y.min() >= 0 and y.max() < spec.n_classes
    # every class must actually occur, or per-dataset accuracies/votes
    # silently measure a smaller problem than the paper's
    assert len(np.unique(np.concatenate([ds.y_train, ds.y_test]))) \
        == spec.n_classes
    for x in (ds.x_train, ds.x_test):
        assert x.dtype == np.float32
        assert x.min() >= 0.0 and x.max() <= 1.0


@pytest.mark.parametrize("name", [n for n in ALL_NAMES
                                  if DATASET_SPECS[n].integer_levels])
def test_integer_level_grids_respected(name):
    """Small-integer UCI features (balance, mammographic) stay on their
    k-level grid end to end: normalization rescales but cannot add levels."""
    spec = DATASET_SPECS[name]
    ds = load_dataset(name)
    for x in (ds.x_train, ds.x_test):
        for j in range(spec.n_features):
            assert len(np.unique(x[:, j])) <= spec.integer_levels


@pytest.mark.parametrize("name", ALL_NAMES)
def test_load_dataset_deterministic(name):
    a, b = load_dataset(name), load_dataset(name)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    np.testing.assert_array_equal(a.y_train, b.y_train)
    np.testing.assert_array_equal(a.x_test, b.x_test)
    np.testing.assert_array_equal(a.y_test, b.y_test)


@pytest.mark.parametrize("name", ALL_NAMES)
def test_normalization_uses_train_statistics_only(name):
    """No test leakage: the loaded arrays equal min-max normalization with
    statistics computed from the raw TRAIN split alone."""
    spec = DATASET_SPECS[name]
    x, y = _generate(spec)
    xtr_raw, ytr, xte_raw, yte = train_test_split(x, y, 0.3, seed=0)
    want_tr, want_te = _normalize01(xtr_raw, xte_raw)
    ds = load_dataset(name)
    np.testing.assert_array_equal(ds.x_train, want_tr)
    np.testing.assert_array_equal(ds.x_test, want_te)
    np.testing.assert_array_equal(ds.y_train, ytr)
    np.testing.assert_array_equal(ds.y_test, yte)
    # train stats span the full [0, 1] range; test merely lands inside it
    lo, hi = ds.x_train.min(axis=0), ds.x_train.max(axis=0)
    spanned = (np.asarray(xtr_raw).max(axis=0)
               - np.asarray(xtr_raw).min(axis=0)) > 1e-9
    assert np.all(lo[spanned] == 0.0)
    assert np.all(hi[spanned] == 1.0)


def test_quantize_u8_master_grid():
    x = np.array([0.0, 0.5, 1.0, 0.999999], np.float32)
    q = quantize_u8(x)
    assert q.dtype == np.uint8
    np.testing.assert_array_equal(q, [0, 128, 255, 255])
