"""Family-pluggable search engine (DESIGN.md §15).

Pins the `ClassifierFamily` seam from both sides:

  - the tree path is UNCHANGED: tree `pareto.json` payloads carry the new
    `family` tag yet round-trip through the legacy loader, and the legacy
    validator refuses foreign families with a clear error;
  - the printed-MLP family is a full citizen: reference == kernel fitness
    bit-for-bit, `run_search --out` emits + verifies RTL through the same
    oracle triangle, artifacts load back and serve through
    `runtime.classify.ClassifyServer` bit-exact against the gate-level
    netlist simulation;
  - sweep machinery is family-aware: `plan_buckets` never merges across
    families, padded problems stack into one vmapped fitness whose rows are
    bit-identical to the per-problem serial oracle, and `unpad_genes`
    inverts the padded (bits, margin) gene layout exactly.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import search
from repro.core import netlist
from repro.datasets import load_dataset
from repro.families import FAMILIES, family_of, family_of_payload, get_family
from repro.families import printed_mlp as pm
from repro.runtime.classify import ClassifyServer
from repro.search import sweep
from repro.search.artifact import load_pareto_artifact, validate_payload


@pytest.fixture(scope="module")
def mlp_problem():
    return pm.build_problem("seeds", n_hidden=4, n_steps=120)


@pytest.fixture(scope="module")
def tree_problem():
    from repro.core.train import train_tree
    from repro.core.tree import to_parallel
    ds = load_dataset("seeds")
    pt = to_parallel(train_tree(ds.x_train, ds.y_train, ds.n_classes))
    return search.build_tree_problem(pt, ds.x_test, ds.y_test)


# ---------------------------------------------------------------------------
# registry + tree regression
# ---------------------------------------------------------------------------

def test_registry_and_dispatch(mlp_problem, tree_problem):
    assert set(FAMILIES) == {"tree", "mlp"}
    assert family_of(tree_problem).name == "tree"
    assert family_of(mlp_problem).name == "mlp"
    with pytest.raises(ValueError, match="unknown classifier family"):
        get_family("forest2000")
    with pytest.raises(TypeError):
        family_of(object())


def test_tree_artifact_family_tag_round_trip(tree_problem, tmp_path):
    """Tree pareto.json gains family='tree' and still loads through the
    legacy single-family loader — the zero-behavior-change contract."""
    out = str(tmp_path / "tree_run")
    search.run_search(tree_problem, backend="reference", pop_size=8,
                      n_generations=2, out_dir=out, dataset="seeds")
    with open(os.path.join(out, "pareto.json")) as f:
        payload = json.load(f)
    assert payload["family"] == "tree"
    validate_payload(payload)                      # legacy validator accepts
    art = load_pareto_artifact(os.path.join(out, "pareto.json"))
    assert art.family == "tree"
    assert art.n_trees == 1 and len(art.points) >= 1
    # the legacy tree validator must refuse foreign families loudly…
    foreign = dict(payload, family="mlp")
    with pytest.raises(ValueError, match="family"):
        validate_payload(foreign)
    # …and family_of_payload must route untagged payloads to the tree family
    untagged = {k: v for k, v in payload.items() if k != "family"}
    assert family_of_payload(untagged).name == "tree"


# ---------------------------------------------------------------------------
# printed-MLP fitness: reference == kernel, exact seed
# ---------------------------------------------------------------------------

def test_mlp_reference_equals_kernel_fitness(mlp_problem):
    ref = pm.make_reference_fitness(mlp_problem)
    ker = pm.make_kernel_fitness(mlp_problem, interpret=True)
    rng = np.random.default_rng(0)
    pop = jnp.asarray(rng.uniform(size=(16, mlp_problem.n_genes)),
                      jnp.float32)
    np.testing.assert_array_equal(np.asarray(ref(pop)), np.asarray(ker(pop)))


def test_mlp_exact_genes_near_origin(mlp_problem):
    """The seeded exact design decodes to (acc_loss, norm_area) == (0, 1)
    up to jit fusion rounding (same ulp-level story as the tree family)."""
    ref = pm.make_reference_fitness(mlp_problem)
    objs = np.asarray(ref(jnp.asarray(mlp_problem.exact_genes()[None])))
    np.testing.assert_allclose(objs[0], [0.0, 1.0], atol=1e-6)
    bits, margin = pm.decode_design(mlp_problem.exact_genes())
    assert (bits == pm.MASTER_WBITS).all() and (margin == 0).all()


# ---------------------------------------------------------------------------
# printed-MLP full loop: search -> RTL-verified artifact -> serving
# ---------------------------------------------------------------------------

def test_mlp_full_loop_artifact_and_serving(mlp_problem, tmp_path):
    out = str(tmp_path / "mlp_run")
    search.run_search(mlp_problem, backend="reference", pop_size=8,
                      n_generations=2, out_dir=out, dataset="seeds",
                      emit_rtl=True, verify_rtl=True)
    with open(os.path.join(out, "pareto.json")) as f:
        payload = json.load(f)
    assert payload["family"] == "mlp"
    assert payload["rtl_verified"] is True
    # the legacy loader dispatches by tag to the MLP artifact class
    art = load_pareto_artifact(os.path.join(out, "pareto.json"))
    assert art.family == "mlp"
    assert art.n_hidden == 4 and art.n_classes == mlp_problem.n_classes
    # schema is enforced: dropping a required key is a loud ValueError
    broken = dict(payload)
    del broken["shift"]
    with pytest.raises(ValueError, match="shift"):
        pm.validate_payload(broken)
    # serve the best point and pin it to the gate-level netlist oracle
    ds = load_dataset("seeds")
    idx = art.best_under_loss(1.0)
    server = ClassifyServer.from_artifact(art, idx, backend="reference")
    got = server.classify(ds.x_test)
    w1, w2 = art.point_design(idx)
    circuit = netlist.build_mlp_circuit(w1, w2, art.shift, art.n_classes)
    want = np.asarray(netlist.simulate(circuit, server.featurize(ds.x_test)))
    np.testing.assert_array_equal(got, want)
    acc = float((got == ds.y_test).mean())
    assert acc == pytest.approx(art.point_accuracy(idx))


# ---------------------------------------------------------------------------
# sweep: family-pure buckets, vmapped == serial, unpad round-trip
# ---------------------------------------------------------------------------

def test_plan_buckets_never_merge_across_families(mlp_problem, tree_problem):
    problems = {"seeds": tree_problem, "seeds_mlp": mlp_problem}
    buckets = sweep.plan_buckets(problems, max_buckets=1)
    fams = {b.family for b in buckets}
    assert fams == {"tree", "mlp"}
    for b in buckets:
        assert {family_of(problems[n]).name for n in b.names} == {b.family}


def test_mlp_vmapped_bucket_matches_serial(mlp_problem):
    """Two MLP problems padded into one bucket: the vmapped stacked fitness
    is bit-identical to each problem's serial objectives at the same padded
    dims — the sweep-correctness invariant (DESIGN.md §11/§15)."""
    other = pm.build_problem("vertebral", n_hidden=3, n_steps=120)
    fam = get_family("mlp")
    dims = tuple(max(a, b) for a, b in zip(
        fam.problem_dims(mlp_problem), fam.problem_dims(other)))
    ops = [fam.pad_problem(p, dims) for p in (mlp_problem, other)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ops)
    n_genes = fam.padded_n_genes(dims)
    rng = np.random.default_rng(1)
    pops = jnp.asarray(rng.uniform(size=(2, 12, n_genes)), jnp.float32)
    batched = jax.jit(jax.vmap(pm.population_objectives))(stacked, pops)
    serial = jax.jit(pm.population_objectives)
    for i in range(2):
        np.testing.assert_array_equal(np.asarray(batched[i]),
                                      np.asarray(serial(ops[i], pops[i])))


def test_tree_unpad_genes_relocates_vote_gene(tree_problem):
    """§16 layout: comparator genes unpad as a prefix slice, but the trailing
    design-level vote gene must come from the LAST padded column."""
    from repro.core import quant
    fam = get_family("tree")
    (bucket,) = sweep.plan_buckets({"t": tree_problem}, max_buckets=1)
    dims = (2 * bucket.dims[0],) + tuple(bucket.dims[1:])
    n_genes = fam.padded_n_genes(dims)
    assert n_genes == 3 * dims[0] + 1
    rng = np.random.default_rng(5)
    padded_pop = rng.uniform(size=(4, n_genes)).astype(np.float32)
    unpadded = fam.unpad_genes(tree_problem, padded_pop, dims)
    assert unpadded.shape == (4, tree_problem.n_genes)
    n_comp_genes = tree_problem.n_genes - 1
    np.testing.assert_array_equal(unpadded[:, :n_comp_genes],
                                  padded_pop[:, :n_comp_genes])
    np.testing.assert_array_equal(unpadded[:, -1], padded_pop[:, -1])
    # padded exact genes decode to the exact design on the REAL slice
    exact = fam.padded_exact_genes(dims)
    bits, marg, trunc, vote = quant.decode_tree_genes(
        jnp.asarray(fam.unpad_genes(tree_problem, exact[None], dims)[0]))
    assert (np.asarray(bits) == 8).all() and (np.asarray(marg) == 0).all()
    assert (np.asarray(trunc) == 0).all() and int(vote) == 0


def test_mlp_unpad_genes_round_trip(mlp_problem):
    fam = get_family("mlp")
    dims = (8, 4, 16, 256)          # strictly larger than seeds h=4
    n_genes = fam.padded_n_genes(dims)
    rng = np.random.default_rng(2)
    padded_pop = rng.uniform(size=(5, n_genes)).astype(np.float32)
    unpadded = fam.unpad_genes(mlp_problem, padded_pop, dims)
    assert unpadded.shape == (5, mlp_problem.n_genes)
    h, hp = mlp_problem.n_hidden, dims[0]
    np.testing.assert_array_equal(unpadded[:, :2 * h],
                                  padded_pop[:, :2 * h])
    np.testing.assert_array_equal(unpadded[:, 2 * h:],
                                  padded_pop[:, 2 * hp:2 * hp + unpadded.shape[1] - 2 * h])
    # padded exact genes decode to the exact design on the REAL slice
    exact = fam.padded_exact_genes(dims)
    bits, margin = pm.decode_design(
        fam.unpad_genes(mlp_problem, exact[None], dims)[0])
    assert (bits == pm.MASTER_WBITS).all() and (margin == 0).all()
