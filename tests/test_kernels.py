"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), shape sweeps."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.datasets import load_dataset, quantize_u8
from repro.core.train import train_tree
from repro.core.tree import to_parallel, ptree_to_jnp, predict_quantized
from repro.core import nsga2, quant
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# tree_infer
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tree_setup():
    ds = load_dataset("vertebral")
    tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
    pt = to_parallel(tree)
    x8 = quantize_u8(ds.x_test).astype(np.int32)
    return ds, pt, x8


def test_tree_infer_matches_core_reference(tree_setup):
    """Kernel == the core.tree quantized predictor for a random population."""
    ds, pt, x8 = tree_setup
    operands = ops.prepare_tree_operands(pt, ds.n_features)
    rng = np.random.default_rng(0)
    genes = jnp.asarray(
        rng.uniform(0, 1, (9, 3 * pt.n_comparators + 1)).astype(np.float32))
    # the core.tree oracle predates §16 approximation genes: zero them
    genes = genes.at[:, 2::3].set(0.0).at[:, -1].set(0.0)
    scale, thr, vote_cap = ops.decode_population(jnp.asarray(pt.threshold),
                                                 genes)
    preds = ops.tree_infer_predict(jnp.asarray(x8), operands, scale, thr,
                                   vote_cap, interpret=True)
    pj = ptree_to_jnp(pt)
    for i in range(genes.shape[0]):
        bits, marg, _, _ = quant.decode_tree_genes(genes[i])
        want = predict_quantized(jnp.asarray(x8), pj, bits, marg)
        np.testing.assert_array_equal(np.asarray(preds[i]), np.asarray(want))


def test_tree_infer_exact_genes_match_float_tree(tree_setup):
    ds, pt, x8 = tree_setup
    operands = ops.prepare_tree_operands(pt, ds.n_features)
    genes = jnp.asarray(quant.exact_tree_genes(pt.n_comparators))[None]
    scale, thr, vote_cap = ops.decode_population(jnp.asarray(pt.threshold),
                                                 genes)
    preds = ops.tree_infer_predict(jnp.asarray(x8), operands, scale, thr,
                                   vote_cap, interpret=True)
    pj = ptree_to_jnp(pt)
    bits = jnp.full(pt.n_comparators, 8, jnp.int32)
    marg = jnp.zeros(pt.n_comparators, jnp.int32)
    want = predict_quantized(jnp.asarray(x8), pj, bits, marg)
    np.testing.assert_array_equal(np.asarray(preds[0]), np.asarray(want))


def test_tree_infer_kernel_vs_ref_oracle_padded_ops(tree_setup):
    """Raw kernel vs ref.py on identical padded operands (several blockings)."""
    ds, pt, x8 = tree_setup
    operands = ops.prepare_tree_operands(pt, ds.n_features)
    sel, path_t, target, cls1h = operands
    rng = np.random.default_rng(1)
    n = sel.shape[1]
    p = 4
    bits = rng.integers(2, 9, (p, n))
    scale = np.exp2(-(8 - bits)).astype(np.float32)
    thr = rng.integers(0, 256, (p, n)).astype(np.float32)
    b = 512
    x8f = rng.integers(0, 256, (b, sel.shape[0])).astype(np.float32)
    want = ref.tree_infer_scores(jnp.asarray(x8f), sel, jnp.asarray(scale),
                                 jnp.asarray(thr), path_t, target, cls1h)
    from repro.kernels.tree_infer import tree_infer_scores
    for block_b in (128, 256, 512):
        got = tree_infer_scores(jnp.asarray(x8f), sel, jnp.asarray(scale),
                                jnp.asarray(thr), path_t, target, cls1h,
                                block_b=block_b, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0, atol=0)


# ---------------------------------------------------------------------------
# domination
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2**31 - 1), p=st.integers(2, 300),
       m=st.integers(1, 4))
def test_domination_kernel_matches_oracle(seed, p, m):
    rng = np.random.default_rng(seed)
    objs = jnp.asarray(rng.integers(0, 5, (p, m)).astype(np.float32))
    got = ops.domination_matrix(objs, interpret=True)
    want = ref.domination_matrix(objs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_domination_kernel_plugs_into_nsga2():
    rng = np.random.default_rng(3)
    objs = jnp.asarray(rng.uniform(0, 1, (64, 2)).astype(np.float32))
    rank_kernel = nsga2.non_dominated_sort(
        objs, ops.domination_matrix_bool(objs, interpret=True))
    rank_ref = nsga2.non_dominated_sort(objs)
    np.testing.assert_array_equal(np.asarray(rank_kernel), np.asarray(rank_ref))


@pytest.mark.parametrize("pi,pj,m", [
    (8, 16, 2), (130, 64, 3), (5, 300, 2), (64, 64, 4),
])
def test_domination_block_rectangular_matches_oracle(pi, pj, m):
    """The sharded-sort entry point (DESIGN.md §13): a (Pi, Pj) row block of
    the domination matrix, rows and columns from DIFFERENT populations, must
    equal the rectangular jnp oracle exactly (incl. internal +inf padding)."""
    rng = np.random.default_rng(pi * 1000 + pj)
    a = jnp.asarray(rng.integers(0, 5, (pi, m)).astype(np.float32))
    b = jnp.asarray(rng.integers(0, 5, (pj, m)).astype(np.float32))
    got = ops.domination_block_bool(a, b, interpret=True)
    want = ref.domination_matrix(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_domination_block_rejects_mismatched_objectives():
    a = jnp.zeros((8, 2), dtype=jnp.float32)
    b = jnp.zeros((8, 3), dtype=jnp.float32)
    with pytest.raises(ValueError):
        ops.domination_block(a, b, interpret=True)


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (128, 512, 256), (256, 1024, 512), (100, 300, 77), (1, 512, 640),
    (257, 129, 385),
])
def test_qmatmul_matches_oracle_shapes(m, k, n):
    rng = np.random.default_rng(m * 7 + k * 3 + n)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.integers(-8, 8, (k, n)).astype(np.int8))
    s = jnp.asarray(rng.uniform(0.01, 0.1, (n,)).astype(np.float32))
    got = ops.qmatmul(x, w, s, interpret=True)
    want = ref.qmatmul(x, w, s.reshape(1, -1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qmatmul_dtypes(dtype):
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32)).astype(dtype)
    w = jnp.asarray(rng.integers(-2, 3, (256, 128)).astype(np.int8))
    s = jnp.asarray(np.full((128,), 0.5, np.float32))
    got = ops.qmatmul(x, w, s, interpret=True)
    want = ref.qmatmul(x.astype(jnp.float32), w, s.reshape(1, -1))
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_qmatmul_blocking_sweep():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(256, 1024)).astype(np.float32))
    w = jnp.asarray(rng.integers(-128, 128, (1024, 256)).astype(np.int8))
    s = jnp.asarray(rng.uniform(0.001, 0.01, (256,)).astype(np.float32))
    want = ref.qmatmul(x, w, s.reshape(1, -1))
    for bm, bn, bk in [(128, 128, 128), (256, 128, 512), (128, 256, 1024)]:
        got = ops.qmatmul(x, w, s, block_m=bm, block_n=bn, block_k=bk,
                          interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-3)


def test_kernel_fitness_equals_reference_fitness(tree_setup):
    """The kernel-backed GA fitness is bit-identical to the vmap reference."""
    from repro.core import approx
    ds, pt, x8 = tree_setup
    prob = approx.build_problem(pt, ds.x_test, ds.y_test)
    f_ref = approx.make_fitness_fn(prob)
    f_ker = approx.make_fitness_fn_kernel(prob, pt, ds.n_features, interpret=True)
    g = jax.random.uniform(jax.random.PRNGKey(7), (24, prob.n_genes))
    np.testing.assert_allclose(np.asarray(f_ref(g)), np.asarray(f_ker(g)),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,skv,hd,group", [
    (256, 256, 64, 1), (512, 512, 128, 4), (256, 512, 64, 2),
])
def test_flash_attention_matches_oracle(sq, skv, hd, group):
    from repro.kernels.flash_attn import flash_attention
    rng = np.random.default_rng(sq + skv + hd)
    hkv = 4
    h = hkv * group
    q = jnp.asarray(rng.normal(size=(h, sq, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(hkv, skv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(hkv, skv, hd)).astype(np.float32))
    got = flash_attention(q, k, v, group=group, block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention(q, k, v, group=group)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16_and_softcap():
    from repro.kernels.flash_attn import flash_attention
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(2, 256, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 256, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 256, 64))).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, group=1, softcap=30.0, block_q=128,
                          block_k=128, interpret=True)
    want = ref.flash_attention(q, k, v, group=1, softcap=30.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.05, atol=0.05)


def test_flash_attention_blocking_sweep():
    from repro.kernels.flash_attn import flash_attention
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    want = ref.flash_attention(q, k, v)
    for bq, bk in [(128, 256), (256, 128), (512, 512)]:
        got = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
