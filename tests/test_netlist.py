"""The hardware loop (DESIGN.md §10): gate-level netlist IR, batched
simulation oracle, forest RTL emission, and the verified pareto artifact.

Edge cases the RTL layer must survive: constant-false comparators
(t' = 2^p - 1), single-leaf trees, non-power-of-two class counts; plus
hypothesis-driven gene draws against the sequential descent oracle and the
acceptance round-trip — every pareto point of a seeds tree and a
vertebral 4-tree forest bit-exact across netlist sim / predict_votes /
kernel backend, re-materializable from pareto.json alone.
"""
import json
import os

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import area, forest as forest_mod, netlist, quant, rtl
from repro.core.train import train_tree
from repro.core.tree import (ParallelTree, concatenate_ptrees,
                             predict_descent_quantized, to_parallel)
from repro.datasets import load_dataset, quantize_u8
from repro import search
from repro.search.problem import decode_chromosome, predict_votes


@pytest.fixture(scope="module")
def seeds_tree():
    ds = load_dataset("seeds")
    tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
    return ds, tree, to_parallel(tree)


def _legacy_genes(rng, n_comparators: int) -> np.ndarray:
    """Random chromosome in the pre-§16 subspace: precision/margin genes
    free, truncation and vote-adder genes zeroed (the oracles below predate
    approximate cells)."""
    g = rng.uniform(0, 1, 3 * n_comparators + 1).astype(np.float32)
    g[2::3] = 0.0
    g[-1] = 0.0
    return g


def _decode(pt_threshold, genes):
    bits, marg, _, _ = quant.decode_tree_genes(jnp.asarray(genes))
    t_sub = quant.substitute(
        quant.threshold_to_int(jnp.asarray(pt_threshold), bits), marg, bits)
    return np.asarray(bits), np.asarray(t_sub)


# ---------------------------------------------------------------------------
# builder-level invariants
# ---------------------------------------------------------------------------

def test_comparator_gates_match_area_model_exhaustively():
    """The netlist comparator lowering IS the construction the area LUT
    prices: AND/OR counts agree for every (t, p)."""
    for p in range(quant.MIN_BITS, quant.MAX_BITS + 1):
        for t in range(1 << p):
            nb = netlist.NetlistBuilder()
            nb.comparator(0, t, p)
            ops = np.asarray(nb.op)
            got = (int((ops == netlist.AND).sum()),
                   int((ops == netlist.OR).sum()))
            assert got == area.comparator_gate_counts(t, p), (t, p)


def test_truncated_comparator_gates_match_area_model_exhaustively():
    """EVERY truncated-cell variant — p in [MIN_BITS, MAX_BITS], k in
    [0, MAX_TRUNC], all 2^p thresholds — lowered through the real
    `build_tree_cells` path: gate counts equal
    `core.area.trunc_comparator_gate_counts` (DESIGN.md §16), so the GA's
    area quanta and the emitted hardware cannot drift apart."""
    one_comp = ParallelTree(
        feature=np.zeros(1, np.int32), threshold=np.zeros(1, np.float32),
        path=np.zeros((0, 1), np.int8), path_len=np.zeros(0, np.int32),
        n_neg=np.zeros(0, np.int32), leaf_class=np.zeros(0, np.int32),
        n_classes=2)
    for p in range(quant.MIN_BITS, quant.MAX_BITS + 1):
        for k in range(quant.MAX_TRUNC + 1):
            for t in range(1 << p):
                nb = netlist.NetlistBuilder()
                cells = netlist.build_tree_cells(
                    nb, one_comp, np.array([p]), np.array([t]), 2,
                    trunc=np.array([k]))
                ops = np.asarray(nb.op)
                got = (int((ops == netlist.AND).sum()),
                       int((ops == netlist.OR).sum()))
                assert got == area.trunc_comparator_gate_counts(t, p, k), \
                    (t, p, k)
                assert cells.comparators[0].trunc == k
    # fully-truncated minimum-width cells degenerate to constant false
    assert area.trunc_comparator_gate_counts(1, 2, 2) == (0, 0)


@settings(deadline=None, max_examples=80)
@given(p=st.integers(quant.MIN_BITS, quant.MAX_BITS),
       k=st.integers(0, quant.MAX_TRUNC),
       t_raw=st.integers(0, (1 << quant.MAX_BITS) - 1))
def test_truncation_flips_only_within_threshold_block(p, k, t_raw):
    """k-LSB truncation can only flip decisions for codes in the same
    2^k-aligned block as the threshold (equivalently: within the bottom
    2^k codes above it) — and every flip is True -> False, never the
    reverse. This is the §16 bound on how far a truncated cell can stray
    from the exact comparator."""
    t = t_raw % (1 << p)
    x = np.arange(1 << p)
    exact = x > t
    truncated = (x >> k) > (t >> k)
    flips = np.flatnonzero(exact != truncated)
    assert np.all((flips >> k) == (t >> k))        # same 2^k block as t
    assert np.all((flips - t) < (1 << k))          # within 2^k codes of t
    assert flips.size <= (1 << k) - 1
    assert np.all(exact[flips])                    # only True -> False


def test_vote_adder_pricing_matches_isolated_lowering():
    """`area.vote_adder_units` prices exactly the gate inventory of the
    isolated vote-stage harness; the approximate OR-tree is never costlier
    than the exact popcount adder, and K = 1 designs have no adder at all."""
    for n_trees in (2, 3, 5):
        for n_classes in (2, 5):
            for approx in (False, True):
                counts = netlist.vote_adder_gate_counts(n_trees, n_classes,
                                                        approx=approx)
                units = area.vote_adder_units(n_trees, n_classes, approx)
                want = area.gate_area_mm2(*counts) / area.AREA_QUANTUM_MM2
                assert units == round(want)
                assert units > 0
            assert (area.vote_adder_units(n_trees, n_classes, True)
                    <= area.vote_adder_units(n_trees, n_classes, False))
    assert area.vote_adder_units(1, 5, False) == 0
    assert area.vote_adder_units(1, 5, True) == 0


def test_constant_false_comparator_folds_away(seeds_tree):
    """t' = 2^p - 1 comparators fold to constant false — in the netlist, in
    the emitted Verilog, and in the simulated predictions."""
    _, tree, pt = seeds_tree
    bits = np.full(pt.n_comparators, 3, np.int64)
    t_sub = np.full(pt.n_comparators, (1 << 3) - 1, np.int64)  # all const
    nb = netlist.NetlistBuilder()
    cells = netlist.build_tree_cells(nb, pt, bits, t_sub, pt.n_classes)
    assert all(c.wire == nb.zero for c in cells.comparators)

    v = rtl.emit_verilog(pt, bits, t_sub)
    assert v.count("= 1'b0;") >= pt.n_comparators

    # every decision is False -> descent always goes left; sim must agree
    x8 = np.arange(256, dtype=np.int32)[:, None].repeat(
        int(pt.feature.max()) + 1, axis=1)
    circ = netlist.build_circuit(pt, bits, t_sub, pt.n_classes)
    internal = np.flatnonzero(tree.feature >= 0)
    bf = np.zeros(tree.n_nodes, np.int64)
    bf[internal] = bits
    # saturating margin clips t' to 2^3 - 1 = 7 everywhere in the oracle too
    want = predict_descent_quantized(x8, tree, bf,
                                     np.full(tree.n_nodes, 7, np.int64))
    got = np.asarray(netlist.simulate(circ, x8))
    np.testing.assert_array_equal(got, want)
    assert len(set(got.tolist())) == 1  # constant circuit


def test_single_leaf_tree():
    """A tree with zero comparators is a constant circuit and a legal,
    input-less Verilog module."""
    pt = ParallelTree(
        feature=np.zeros(0, np.int32), threshold=np.zeros(0, np.float32),
        path=np.zeros((1, 1), np.int8), path_len=np.zeros(1, np.int32),
        n_neg=np.zeros(1, np.int32), leaf_class=np.array([2], np.int32),
        n_classes=4)
    circ = netlist.build_circuit(pt, np.zeros(0), np.zeros(0), 4)
    x8 = np.zeros((5, 3), np.int32)
    np.testing.assert_array_equal(np.asarray(netlist.simulate(circ, x8)),
                                  np.full(5, 2))
    v = rtl.emit_verilog(pt, np.zeros(0), np.zeros(0))
    assert "wire leaf0 = 1'b1;" in v and "input" not in v
    assert "assign class_out[1] = leaf0;" in v  # class 2 = 0b10


def test_forest_with_non_power_of_two_classes():
    """C = 5 classes: vote counts, argmax chain and tie-breaking must match
    the looped forest oracle (ties -> lowest class index)."""
    rng = np.random.default_rng(2)
    x = rng.uniform(0, 1, (300, 4)).astype(np.float32)
    y = np.clip((x[:, 0] * 5).astype(np.int64)
                + (rng.uniform(size=300) < 0.2), 0, 4)
    fr = forest_mod.train_forest(x, y, 5, n_trees=3, seed=1)
    x8 = quantize_u8(rng.uniform(0, 1, (96, 4)).astype(np.float32))
    x8 = x8.astype(np.int32)
    thresholds = np.concatenate([p.threshold for p in fr.ptrees])
    for trial in range(3):
        genes = _legacy_genes(rng, fr.n_comparators)
        bits, t_sub = _decode(thresholds, genes)
        bits_j, marg_j, _, _ = quant.decode_tree_genes(jnp.asarray(genes))
        circ = netlist.build_circuit(fr.ptrees, bits, t_sub, 5)
        got = np.asarray(netlist.simulate(circ, x8))
        want = np.asarray(forest_mod.forest_predict(
            fr, jnp.asarray(x8), bits_j, marg_j))
        np.testing.assert_array_equal(got, want)
    # the Verilog carries the 3-bit class encoding and the full argmax chain
    v = rtl.emit_forest_verilog(fr.ptrees, bits, t_sub, 5)
    assert "wire [2:0] idx0 = 3'd0;" in v
    assert "assign class_out = idx4;" in v


@settings(max_examples=10)
@given(st.integers(0, 2**31 - 1))
def test_netlist_sim_matches_descent_oracle(seeds_tree, draw_seed):
    """Hypothesis-driven gene draws: the gate-level simulation of the emitted
    circuit equals the sequential quantized descent, bit for bit."""
    ds, tree, pt = seeds_tree
    rng = np.random.default_rng(draw_seed)
    genes = _legacy_genes(rng, pt.n_comparators)
    bits, t_sub = _decode(pt.threshold, genes)
    _, marg, _, _ = quant.decode_tree_genes(jnp.asarray(genes))
    circ = netlist.build_circuit(pt, bits, t_sub, pt.n_classes)
    x8 = quantize_u8(ds.x_test).astype(np.int32)
    internal = np.flatnonzero(tree.feature >= 0)
    bf = np.zeros(tree.n_nodes, np.int64)
    mf = np.zeros(tree.n_nodes, np.int64)
    bf[internal] = bits
    mf[internal] = np.asarray(marg)
    want = predict_descent_quantized(x8, tree, bf, mf)
    np.testing.assert_array_equal(np.asarray(netlist.simulate(circ, x8)),
                                  want)


def test_cross_tree_cse_shares_comparators():
    """Two identical trees: hash-consing shares every comparator/leaf gate,
    so the forest netlist costs vote logic only — the sharing gap the
    additive LUT estimate cannot see."""
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 1, (200, 3)).astype(np.float32)
    y = (x[:, 0] > 0.5).astype(np.int64)
    pt = to_parallel(train_tree(x, y, 2))
    bits = np.full(pt.n_comparators, 8, np.int64)
    t_sub = np.clip(np.floor(pt.threshold * 256).astype(np.int64), 0, 255)
    one = netlist.build_circuit(pt, bits, t_sub, 2)
    two = netlist.build_circuit([pt, pt], np.tile(bits, 2),
                                np.tile(t_sub, 2), 2)
    c1, c2 = netlist.gate_counts(one), netlist.gate_counts(two)
    # tree logic counted once; only popcount/argmax gates are new
    assert c2["and"] + c2["or"] < 2 * (c1["and"] + c1["or"]) + 20


def test_problem_ptrees_roundtrip():
    """problem_ptrees inverts the block-diagonal concatenation exactly."""
    ds = load_dataset("vertebral")
    fr = forest_mod.train_forest(ds.x_train, ds.y_train, ds.n_classes,
                                 n_trees=3)
    prob = search.build_forest_problem(fr, ds.x_test, ds.y_test)
    back = search.problem_ptrees(prob)
    want = concatenate_ptrees(fr.ptrees)
    got = concatenate_ptrees(back)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])


# ---------------------------------------------------------------------------
# the acceptance round-trip: verified pareto artifacts, tree AND forest
# ---------------------------------------------------------------------------

def _roundtrip_t_int(artifact):
    """Re-materialize every point's t_int from the artifact alone."""
    thr = np.asarray(artifact["threshold"], np.float32)
    for p in artifact["pareto"]:
        bits = np.asarray(p["bits"], np.int64)
        marg = np.asarray(p["margin"], np.int64)
        t = np.clip(np.floor(thr.astype(np.float64) * (2.0 ** bits)),
                    0, (1 << bits) - 1).astype(np.int64)
        t_sub = np.clip(t + marg, 0, (1 << bits) - 1)
        np.testing.assert_array_equal(t_sub, np.asarray(p["t_int"]))


def _check_verified_artifact(prob, out):
    with open(os.path.join(out, "pareto.json")) as f:
        artifact = json.load(f)
    assert artifact["rtl_verified"] is True
    assert len(artifact["threshold"]) == prob.n_comparators
    for i, p in enumerate(artifact["pareto"]):
        assert p["verified"] is True
        assert len(p["t_int"]) == prob.n_comparators
        assert p["area_netlist_mm2"] > 0
        assert os.path.exists(os.path.join(out, p["rtl"]))
    _roundtrip_t_int(artifact)
    return artifact


def test_pareto_points_verified_seeds_tree(tmp_path):
    """Acceptance: every pareto point of a seeds tree — netlist sim ==
    predict_votes == kernel backend over the full test set (the engine
    raises otherwise), artifact self-contained."""
    ds = load_dataset("seeds")
    pt = to_parallel(train_tree(ds.x_train, ds.y_train, ds.n_classes))
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    out = str(tmp_path / "tree")
    search.run_search(prob, pop_size=8, n_generations=2, out_dir=out,
                      emit_rtl=True, verify_rtl=True)
    artifact = _check_verified_artifact(prob, out)
    assert artifact["n_trees"] == 1


def test_pareto_points_verified_vertebral_forest(tmp_path):
    """Acceptance: same, for a vertebral 4-tree forest — the emitted design
    includes the majority-vote adder tree."""
    ds = load_dataset("vertebral")
    fr = forest_mod.train_forest(ds.x_train, ds.y_train, ds.n_classes,
                                 n_trees=4)
    prob = search.build_forest_problem(fr, ds.x_test, ds.y_test)
    out = str(tmp_path / "forest")
    search.run_search(prob, pop_size=8, n_generations=2, out_dir=out,
                      emit_rtl=True, verify_rtl=True)
    artifact = _check_verified_artifact(prob, out)
    assert artifact["n_trees"] == 4
    with open(os.path.join(out, artifact["pareto"][0]["rtl"])) as f:
        v = f.read()
    assert "majority-vote adder tree" in v
    assert v.count("endmodule") == 5  # 4 tree modules + top

    # explicit three-way re-check of one point, independent of the engine.
    # decode_chromosome returns the EFFECTIVE design (§16 truncation already
    # folded into bits/t_sub), so the netlist lowers it with trunc unset.
    g = jnp.asarray(artifact["pareto"][0]["genes"], jnp.float32)
    bits, t_sub, vote_cap = decode_chromosome(prob, g)
    vote_adder = "approx" if np.isfinite(float(vote_cap)) else "exact"
    circ = netlist.build_circuit(search.problem_ptrees(prob),
                                 np.asarray(bits), np.asarray(t_sub),
                                 prob.n_classes, vote_adder=vote_adder)
    sim = np.asarray(netlist.simulate(circ, prob.x8))
    np.testing.assert_array_equal(
        sim, np.asarray(predict_votes(prob, bits, t_sub, vote_cap)))


def test_rtl_flags_require_out_dir(seeds_tree):
    ds, _, pt = seeds_tree
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    with pytest.raises(ValueError, match="out_dir"):
        search.run_search(prob, pop_size=8, n_generations=1, verify_rtl=True)
