"""Batched full-suite sweep engine (DESIGN.md §11): inert padding, the
vmapped-vs-serial bit-exactness contract on mixed-shape buckets, bucket
planning, dispatch accounting, artifacts/report, CLI."""
import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import forest as forest_mod
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.datasets import load_dataset
from repro import search
from repro.search import sweep as sweep_mod


@pytest.fixture(scope="module")
def campaign():
    """Mixed-shape, mixed-kind campaign: two single trees + one forest."""
    problems = {}
    for name in ("seeds", "vertebral"):
        ds = load_dataset(name)
        pt = to_parallel(train_tree(ds.x_train, ds.y_train, ds.n_classes))
        problems[name] = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    ds = load_dataset("seeds")
    fr = forest_mod.train_forest(ds.x_train, ds.y_train, ds.n_classes,
                                 n_trees=2)
    problems["seeds_forest2"] = search.build_forest_problem(
        fr, ds.x_test, ds.y_test)
    return problems


def _bucket_dims_for(problems):
    """One merged bucket covering every problem (the mixed-shape case)."""
    (bucket,) = sweep_mod.plan_buckets(problems, max_buckets=1)
    return bucket.dims


# ---------------------------------------------------------------------------
# padding semantics
# ---------------------------------------------------------------------------

def test_pad_genes_are_inert_bitexact(campaign):
    """Two chromosomes that differ ONLY in pad-gene columns produce
    bit-identical objectives and predictions — the masking contract."""
    dims = _bucket_dims_for(campaign)
    rng = np.random.default_rng(0)
    for name, problem in campaign.items():
        pp = sweep_mod.pad_problem(problem, dims)
        g_real = rng.uniform(0, 1, problem.n_genes).astype(np.float32)
        a = rng.uniform(0, 1, pp.n_genes).astype(np.float32)
        b = rng.uniform(0, 1, pp.n_genes).astype(np.float32)
        # §16 layout: comparator genes are a prefix, the design-level vote
        # gene rides in the LAST padded column (TreeFamily.unpad_genes)
        for g in (a, b):
            g[:problem.n_genes - 1] = g_real[:-1]
            g[-1] = g_real[-1]
        oa = np.asarray(sweep_mod.padded_objectives(pp, jnp.asarray(a)))
        ob = np.asarray(sweep_mod.padded_objectives(pp, jnp.asarray(b)))
        np.testing.assert_array_equal(oa, ob, err_msg=name)
        pa = np.asarray(sweep_mod.padded_predict(pp, jnp.asarray(a)))
        pb = np.asarray(sweep_mod.padded_predict(pp, jnp.asarray(b)))
        np.testing.assert_array_equal(pa, pb, err_msg=name)


def test_padded_matches_unpadded_semantics(campaign):
    """Padded evaluation == the unpadded SearchProblem primitives: real-row
    predictions bit-exact, objectives equal to float rounding (the area term
    sums integer quanta, trading last-ulp identity for vmap-order
    invariance)."""
    dims = _bucket_dims_for(campaign)
    rng = np.random.default_rng(1)
    for name, problem in campaign.items():
        pp = sweep_mod.pad_problem(problem, dims)
        b_real = int(problem.x8.shape[0])
        for _ in range(4):
            g_real = rng.uniform(0, 1, problem.n_genes).astype(np.float32)
            g_pad = rng.uniform(0, 1, pp.n_genes).astype(np.float32)
            g_pad[:problem.n_genes - 1] = g_real[:-1]
            g_pad[-1] = g_real[-1]

            bits, t_sub, vote_cap = search.decode_chromosome(
                problem, jnp.asarray(g_real))
            want_pred = np.asarray(
                search.predict_votes(problem, bits, t_sub, vote_cap))
            got_pred = np.asarray(
                sweep_mod.padded_predict(pp, jnp.asarray(g_pad)))[:b_real]
            np.testing.assert_array_equal(got_pred, want_pred, err_msg=name)

            want_obj = np.asarray(
                search.objectives(problem, jnp.asarray(g_real)))
            got_obj = np.asarray(
                sweep_mod.padded_objectives(pp, jnp.asarray(g_pad)))
            np.testing.assert_allclose(got_obj, want_obj, atol=2e-6,
                                       err_msg=name)


def test_pad_problem_rejects_too_small_dims(campaign):
    problem = campaign["vertebral"]
    with pytest.raises(ValueError, match="smaller than"):
        sweep_mod.pad_problem(problem, (8, 8, 8, 8, 8))


# ---------------------------------------------------------------------------
# the acceptance contract: vmapped campaign == serial oracle, bit-exact
# ---------------------------------------------------------------------------

def test_vmapped_bitexact_vs_serial_on_mixed_bucket(campaign):
    """One merged bucket holding two trees + a forest of three different
    shapes: the vmapped campaign's final populations are bit-identical
    array-for-array to the per-problem serial loop."""
    kw = dict(pop_size=8, n_generations=3, seed=0, max_buckets=1)
    vm = sweep_mod.run_sweep(campaign, vmapped=True, **kw)
    sr = sweep_mod.run_sweep(campaign, vmapped=False, **kw)
    assert len(vm.bucket_runs) == 1
    for name in campaign:
        v, s = vm.results[name], sr.results[name]
        np.testing.assert_array_equal(np.asarray(v.state.genes),
                                      np.asarray(s.state.genes),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(v.state.objs),
                                      np.asarray(s.state.objs), err_msg=name)
        np.testing.assert_array_equal(v.pareto_objs, s.pareto_objs,
                                      err_msg=name)
        np.testing.assert_array_equal(v.pareto_genes, s.pareto_genes,
                                      err_msg=name)


def test_vmapped_bitexact_vs_serial_across_buckets(campaign):
    """Same contract when the planner keeps problems in separate buckets.

    (Only the per-dataset PRNG *key* is bucket-plan independent; the padded
    chromosome length is part of the plan, and GA random draws are
    shape-dependent, so different plans legitimately explore differently —
    the contract is vmapped == serial at EQUAL plan.)"""
    kw = dict(pop_size=8, n_generations=3, seed=0, max_buckets=3)
    vm = sweep_mod.run_sweep(campaign, vmapped=True, **kw)
    sr = sweep_mod.run_sweep(campaign, vmapped=False, **kw)
    assert len(vm.bucket_runs) > 1
    for name in campaign:
        np.testing.assert_array_equal(np.asarray(vm.results[name].state.genes),
                                      np.asarray(sr.results[name].state.genes),
                                      err_msg=name)
        np.testing.assert_array_equal(vm.results[name].pareto_objs,
                                      sr.results[name].pareto_objs,
                                      err_msg=name)


# ---------------------------------------------------------------------------
# bucket planning + dispatch accounting
# ---------------------------------------------------------------------------

def test_plan_buckets_pow2_and_merge(campaign):
    buckets = sweep_mod.plan_buckets(campaign, max_buckets=2)
    assert 1 <= len(buckets) <= 2
    covered = sorted(n for b in buckets for n in b.names)
    assert covered == sorted(campaign)
    for b in buckets:
        for name in b.names:
            real = sweep_mod.problem_dims(campaign[name])
            for d_pad, d_real in zip(b.dims, real):
                assert d_pad >= max(d_real, sweep_mod.GRANULE)
                assert d_pad & (d_pad - 1) == 0  # power of two
    # deterministic
    again = sweep_mod.plan_buckets(campaign, max_buckets=2)
    assert buckets == again


def test_plan_buckets_rejects_zero_max(campaign):
    with pytest.raises(ValueError, match="max_buckets"):
        sweep_mod.plan_buckets(campaign, max_buckets=0)


def test_dispatch_accounting_beats_serial_baseline(campaign):
    sweep = sweep_mod.run_sweep(campaign, pop_size=8, n_generations=2,
                                max_buckets=1)
    # one bucket: init + one chunked scan for every problem at once
    assert sweep.n_dispatches == 2
    assert sweep.serial_baseline_dispatches() == 2 * len(campaign)
    assert sweep.n_dispatches < sweep.serial_baseline_dispatches()
    for result in sweep.results.values():
        assert result.n_dispatches == 2
        assert result.n_evaluations == 8 * (1 + 2)


# ---------------------------------------------------------------------------
# artifacts, report, CLI
# ---------------------------------------------------------------------------

def test_sweep_artifacts_unpadded_and_report(campaign, tmp_path):
    out = str(tmp_path / "sweep")
    sweep = sweep_mod.run_sweep(campaign, pop_size=8, n_generations=2,
                                max_buckets=1, out_dir=out)
    for name, problem in campaign.items():
        with open(os.path.join(out, name, "pareto.json")) as f:
            artifact = json.load(f)
        assert artifact["n_trees"] == problem.n_trees
        assert artifact["n_comparators"] == problem.n_comparators
        for point in artifact["pareto"]:
            # genes/bits were unpadded back to the REAL comparator count
            assert len(point["bits"]) == problem.n_comparators
            assert len(point["genes"]) == problem.n_genes
            assert all(2 <= b <= 8 for b in point["bits"])

    json_path, md_path = sweep_mod.write_sweep_report(
        sweep, campaign, out, meta={"pop": 8, "gens": 2})
    with open(json_path) as f:
        report = json.load(f)
    assert report["n_dispatches"] == 2
    assert report["serial_baseline_dispatches"] == 2 * len(campaign)
    assert sorted(report["datasets"]) == sorted(campaign)
    for name in ("seeds", "vertebral"):
        row = report["datasets"][name]
        assert row["paper_accuracy"] > 0
        assert "accuracy_delta" in row
        assert row["netlist_vs_estimated_area"]["n_points"] >= 1
    # the forest stand-in is not a paper scenario: scored without refs
    assert "paper_accuracy" not in report["datasets"]["seeds_forest2"]
    md = open(md_path).read()
    assert "| dataset |" in md and "seeds" in md


def test_run_sweep_validates_config(campaign):
    with pytest.raises(ValueError, match="out_dir"):
        sweep_mod.run_sweep(campaign, pop_size=8, n_generations=1,
                            emit_rtl=True)
    with pytest.raises(ValueError, match="at least one"):
        sweep_mod.run_sweep({})


def test_sweep_cli_smoke(tmp_path, capsys):
    from repro.search.__main__ import main
    out = str(tmp_path / "cli")
    main(["sweep", "--datasets", "seeds,vertebral", "--pop", "8",
          "--gens", "2", "--out", out, "--report"])
    captured = capsys.readouterr().out
    assert "campaign:" in captured and "dispatches" in captured
    assert os.path.exists(os.path.join(out, "seeds", "pareto.json"))
    assert os.path.exists(os.path.join(out, "sweep_report.json"))
    assert os.path.exists(os.path.join(out, "REPORT.md"))


def test_sweep_cli_rejects_unknown_dataset(tmp_path):
    from repro.search.__main__ import main
    with pytest.raises(SystemExit):
        main(["sweep", "--datasets", "nope", "--pop", "8", "--gens", "1"])
