"""Unified search engine: forest parity (fused kernel vs looped vote vs
sequential descent oracle), backend equivalence, checkpoint/resume, CLI."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.datasets import load_dataset, quantize_u8
from repro.core import approx, forest as forest_mod, nsga2, quant
from repro.core.train import train_tree
from repro.core.tree import predict_descent_quantized, to_parallel
from repro.kernels import ops
from repro import search


@pytest.fixture(scope="module")
def forest_setup():
    ds = load_dataset("seeds")
    fr = forest_mod.train_forest(ds.x_train, ds.y_train, ds.n_classes,
                                 n_trees=4)
    x8 = quantize_u8(ds.x_test).astype(np.int32)
    return ds, fr, x8


@pytest.fixture(scope="module")
def tree_setup():
    ds = load_dataset("vertebral")
    tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
    pt = to_parallel(tree)
    return ds, tree, pt


def _legacy_subspace(genes):
    """Zero the approximation genes (trunc at 2::3, trailing vote gene) so
    the pre-§16 per-tree oracles below — which model neither comparator
    truncation nor the saturating vote adder — stay valid comparators."""
    return genes.at[:, 2::3].set(0.0).at[:, -1].set(0.0)


def _descent_vote(fr, x8, bits_all, marg_all):
    """Oracle #2: per-tree sequential descent + majority vote (numpy)."""
    votes = np.zeros((x8.shape[0], fr.n_classes), np.float32)
    off = 0
    for tree, pt in zip(fr.trees, fr.ptrees):
        n = pt.n_comparators
        internal = np.flatnonzero(tree.feature >= 0)
        bits_full = np.zeros(tree.n_nodes, np.int64)
        marg_full = np.zeros(tree.n_nodes, np.int64)
        bits_full[internal] = np.asarray(bits_all[off:off + n])
        marg_full[internal] = np.asarray(marg_all[off:off + n])
        pred = predict_descent_quantized(x8, tree, bits_full, marg_full)
        votes[np.arange(x8.shape[0]), pred] += 1.0
        off += n
    return np.argmax(votes, axis=1)


# ---------------------------------------------------------------------------
# forest parity: fused kernel vs looped forest_predict vs descent oracle
# ---------------------------------------------------------------------------

def test_forest_parity_three_ways(forest_setup):
    """Fused multi-tree kernel == looped forest_predict == descent+vote,
    bit-exact, for random per-comparator (precision, margin) genes."""
    ds, fr, x8 = forest_setup
    thresholds = jnp.concatenate([jnp.asarray(p.threshold) for p in fr.ptrees])
    operands = ops.prepare_forest_operands(fr.ptrees, ds.n_features)
    rng = np.random.default_rng(0)
    genes = _legacy_subspace(jnp.asarray(
        rng.uniform(0, 1, (8, fr.n_genes)).astype(np.float32)))
    scale, thr, vote_cap = ops.decode_population(thresholds, genes)
    preds = ops.tree_infer_predict(jnp.asarray(x8), operands, scale, thr,
                                   vote_cap, interpret=True)
    for i in range(genes.shape[0]):
        bits, marg, _, _ = quant.decode_tree_genes(genes[i])
        looped = forest_mod.forest_predict(fr, jnp.asarray(x8), bits, marg)
        descent = _descent_vote(fr, x8, np.asarray(bits), np.asarray(marg))
        np.testing.assert_array_equal(np.asarray(preds[i]), np.asarray(looped))
        np.testing.assert_array_equal(np.asarray(preds[i]), descent)


def test_forest_parity_leaf_blocked_kernel(forest_setup):
    """Leaf-axis blocking (block_l) never changes the vote accumulation."""
    ds, fr, x8 = forest_setup
    thresholds = jnp.concatenate([jnp.asarray(p.threshold) for p in fr.ptrees])
    operands = ops.prepare_forest_operands(fr.ptrees, ds.n_features)
    rng = np.random.default_rng(1)
    genes = jnp.asarray(rng.uniform(0, 1, (4, fr.n_genes)).astype(np.float32))
    scale, thr, vote_cap = ops.decode_population(thresholds, genes)
    want = ops.tree_infer_predict(jnp.asarray(x8), operands, scale, thr,
                                  vote_cap, interpret=True)
    for block_l in (128, 256):
        got = ops.tree_infer_predict(jnp.asarray(x8), operands, scale, thr,
                                     vote_cap, block_l=block_l,
                                     interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_forest_parity_padded_edge_cases():
    """Uneven tree sizes + tiny forests: padded comparators/leaves/classes
    must never fire. Trees of different depths come from different-sized
    bootstrap samples over noisy data."""
    rng = np.random.default_rng(7)
    x = rng.uniform(0, 1, (160, 5)).astype(np.float32)
    y = ((x[:, 0] + 0.3 * rng.uniform(-1, 1, 160)) > 0.5).astype(np.int64)
    for n_trees in (2, 3):
        fr = forest_mod.train_forest(x, y, 2, n_trees=n_trees, seed=n_trees)
        assert len({p.n_comparators for p in fr.ptrees}) >= 1
        x8 = quantize_u8(rng.uniform(0, 1, (64, 5)).astype(np.float32))
        x8 = x8.astype(np.int32)
        thresholds = jnp.concatenate(
            [jnp.asarray(p.threshold) for p in fr.ptrees])
        operands = ops.prepare_forest_operands(fr.ptrees, 5)
        genes = _legacy_subspace(jnp.asarray(
            rng.uniform(0, 1, (5, fr.n_genes)).astype(np.float32)))
        scale, thr, vote_cap = ops.decode_population(thresholds, genes)
        preds = ops.tree_infer_predict(jnp.asarray(x8), operands, scale, thr,
                                       vote_cap, interpret=True)
        for i in range(genes.shape[0]):
            bits, marg, _, _ = quant.decode_tree_genes(genes[i])
            looped = forest_mod.forest_predict(fr, jnp.asarray(x8), bits, marg)
            descent = _descent_vote(fr, x8, np.asarray(bits), np.asarray(marg))
            np.testing.assert_array_equal(np.asarray(preds[i]),
                                          np.asarray(looped))
            np.testing.assert_array_equal(np.asarray(preds[i]), descent)


def test_forest_reference_backend_matches_looped_fitness(forest_setup):
    """SearchProblem reference fitness == the historical per-tree loop."""
    ds, fr, x8 = forest_setup
    prob = search.build_forest_problem(fr, ds.x_test, ds.y_test)
    fit = search.make_fitness(prob, "reference")
    genes = _legacy_subspace(
        jax.random.uniform(jax.random.PRNGKey(5), (12, prob.n_genes)))
    got = np.asarray(fit(genes))
    y = np.asarray(ds.y_test)
    for i in range(genes.shape[0]):
        bits, marg, _, _ = quant.decode_tree_genes(genes[i])
        pred = np.asarray(
            forest_mod.forest_predict(fr, jnp.asarray(x8), bits, marg))
        acc = np.float32((pred == y).mean())
        np.testing.assert_allclose(
            got[i, 0], np.float32(prob.exact_accuracy) - acc, atol=1e-6)


def test_forest_kernel_backend_bitexact_vs_reference(forest_setup):
    ds, fr, _ = forest_setup
    prob = search.build_forest_problem(fr, ds.x_test, ds.y_test)
    f_ref = search.make_fitness(prob, "reference")
    f_ker = search.make_fitness(prob, "kernel", interpret=True)
    pop = jax.random.uniform(jax.random.PRNGKey(3), (16, prob.n_genes))
    np.testing.assert_array_equal(np.asarray(f_ref(pop)),
                                  np.asarray(f_ker(pop)))


def test_forest_parity_full_gene_space_vs_netlist(forest_setup):
    """Fused kernel == reference predict == gate-level netlist sim over
    random chromosomes spanning the FULL DESIGN.md §16 gene space (precision,
    margin, LSB truncation, vote-adder toggle). The netlist lowers truncation
    independently — by dropping low-bit comparator stages — so agreement here
    is a genuine cross-layer check, not a shared-code tautology."""
    from repro.core import netlist
    ds, fr, x8 = forest_setup
    prob = search.build_forest_problem(fr, ds.x_test, ds.y_test)
    thresholds = jnp.concatenate([jnp.asarray(p.threshold) for p in fr.ptrees])
    operands = ops.prepare_forest_operands(fr.ptrees, ds.n_features)
    rng = np.random.default_rng(23)
    genes = jnp.asarray(
        rng.uniform(0, 1, (6, prob.n_genes)).astype(np.float32))
    # force both vote-adder modes onto the sampled population
    genes = genes.at[0, -1].set(0.0).at[1, -1].set(0.999)
    scale, thr, vote_cap = ops.decode_population(thresholds, genes)
    preds = ops.tree_infer_predict(jnp.asarray(x8), operands, scale, thr,
                                   vote_cap, interpret=True)
    for i in range(genes.shape[0]):
        bits, marg, trunc, vote = quant.decode_tree_genes(genes[i])
        t_sub = quant.substitute(
            quant.threshold_to_int(thresholds, bits), marg, bits)
        cap = jnp.where(vote > 0, jnp.float32(1.0), jnp.float32(jnp.inf))
        ref = search.predict_votes(prob, bits - trunc,
                                   jnp.right_shift(t_sub, trunc), cap)
        circuit = netlist.build_circuit(
            fr.ptrees, np.asarray(bits), np.asarray(t_sub), fr.n_classes,
            trunc=np.asarray(trunc),
            vote_adder="approx" if int(vote) else "exact")
        sim = netlist.simulate(circuit, jnp.asarray(x8))
        np.testing.assert_array_equal(np.asarray(preds[i]), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(preds[i]), np.asarray(sim))


# ---------------------------------------------------------------------------
# single-tree engine parity with the historical pipeline
# ---------------------------------------------------------------------------

def test_single_tree_objectives_match_independent_oracle(tree_setup):
    """SearchProblem objectives vs an independently-coded leaf-decode +
    LUT-area computation (the pre-engine core.approx formulation)."""
    from repro.core import area as area_mod
    from repro.core.tree import predict_quantized, ptree_to_jnp
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    x8 = quantize_u8(ds.x_test).astype(np.int32)
    lut, offsets = area_mod.build_area_lut()
    rng = np.random.default_rng(11)
    genes = _legacy_subspace(jnp.asarray(
        rng.uniform(0, 1, (6, prob.n_genes)).astype(np.float32)))
    fit = search.make_fitness(prob, "reference")
    got = np.asarray(fit(genes))
    pj = ptree_to_jnp(pt)
    for i in range(genes.shape[0]):
        bits, marg, _, _ = quant.decode_tree_genes(genes[i])
        pred = predict_quantized(jnp.asarray(x8), pj, bits, marg)
        acc = np.float32((np.asarray(pred) == ds.y_test).mean())
        t_int = np.asarray(quant.substitute(
            quant.threshold_to_int(jnp.asarray(pt.threshold), bits),
            marg, bits))
        a = lut[offsets[np.asarray(bits)] + t_int].sum() + prob.overhead_mm2
        np.testing.assert_allclose(got[i, 0],
                                   np.float32(prob.exact_accuracy) - acc,
                                   atol=1e-6)
        np.testing.assert_allclose(got[i, 1], a / prob.exact_area_mm2,
                                   rtol=1e-6)


def test_run_search_reference_reproduces_legacy_pipeline(tree_setup):
    """run_search == the historical nsga2.run(make_fitness_fn) pipeline:
    same seed, same genes, same pareto objectives."""
    ds, tree, pt = tree_setup
    prob = approx.build_problem(pt, ds.x_test, ds.y_test)
    result = search.run_search(prob, backend="reference", pop_size=16,
                               n_generations=5, seed=0)
    fit = approx.make_fitness_fn(prob)
    cfg = nsga2.NSGA2Config(pop_size=16, n_generations=5)
    state = nsga2.run(jax.random.PRNGKey(0), fit, prob.n_genes, cfg,
                      seed_genes=quant.exact_tree_genes(pt.n_comparators))
    objs, genes = nsga2.pareto_front(state.objs, state.genes)
    np.testing.assert_array_equal(result.pareto_objs, np.asarray(objs))
    np.testing.assert_array_equal(result.pareto_genes, np.asarray(genes))


def test_run_search_kernel_backend_matches_reference(tree_setup):
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    r_ref = search.run_search(prob, backend="reference", pop_size=12,
                              n_generations=3, seed=1)
    r_ker = search.run_search(prob, backend="kernel", pop_size=12,
                              n_generations=3, seed=1, interpret=True)
    np.testing.assert_array_equal(r_ref.pareto_objs, r_ker.pareto_objs)
    np.testing.assert_array_equal(r_ref.pareto_genes, r_ker.pareto_genes)


# ---------------------------------------------------------------------------
# engine features
# ---------------------------------------------------------------------------

def test_checkpoint_resume_is_bitexact(tree_setup, tmp_path):
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    out = str(tmp_path / "run")
    cfg = search.SearchConfig(pop_size=8, n_generations=4, out_dir=out,
                              checkpoint_every=2)
    full = search.run_search(prob, cfg)
    import shutil
    shutil.rmtree(out)
    search.run_search(prob, cfg, n_generations=2)
    resumed = search.run_search(prob, cfg, resume=True)
    np.testing.assert_array_equal(np.asarray(full.state.genes),
                                  np.asarray(resumed.state.genes))
    np.testing.assert_array_equal(full.pareto_objs, resumed.pareto_objs)


def test_pareto_artifact_written(tree_setup, tmp_path):
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    out = str(tmp_path / "artifacts")
    search.run_search(prob, backend="reference", pop_size=8, n_generations=2,
                      out_dir=out)
    import json, os
    with open(os.path.join(out, "pareto.json")) as f:
        payload = json.load(f)
    assert payload["backend"] == "reference"
    assert payload["n_trees"] == 1
    assert len(payload["pareto"]) >= 1
    p0 = payload["pareto"][0]
    assert len(p0["bits"]) == prob.n_comparators
    assert all(2 <= b <= 8 for b in p0["bits"])


def test_cli_smoke(tmp_path, capsys):
    from repro.search.__main__ import main
    out = str(tmp_path / "cli")
    main(["--dataset", "seeds", "--pop", "8", "--gens", "2", "--out", out])
    captured = capsys.readouterr().out
    assert "pareto front" in captured
    import os
    assert os.path.exists(os.path.join(out, "pareto.json"))


def test_islands_backend_runs(tree_setup):
    """Single-device island search still produces a pareto front."""
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    result = search.run_search(prob, backend="islands", pop_size=16,
                               n_generations=4)
    assert result.pareto_objs.shape[1] == 2
    assert len(result.pareto_objs) >= 1


# ---------------------------------------------------------------------------
# device-resident generation loop (DESIGN.md §9)
# ---------------------------------------------------------------------------

def test_chunked_scan_equals_per_generation_loop(tree_setup):
    """run_search (chunked lax.scan, any chunking) == the per-generation
    host loop, bit-for-bit: same seed, same final population."""
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    fit = search.make_fitness(prob, "reference")
    cfg = nsga2.NSGA2Config(pop_size=10, n_generations=7)
    state = nsga2.init_state(jax.random.PRNGKey(0), fit, prob.n_genes, cfg,
                             seed_genes=prob.exact_genes())
    step = jax.jit(nsga2.make_step(fit, cfg))
    for _ in range(7):
        state = step(state)

    whole = search.run_search(prob, backend="reference", pop_size=10,
                              n_generations=7, seed=0)
    np.testing.assert_array_equal(np.asarray(state.genes),
                                  np.asarray(whole.state.genes))
    np.testing.assert_array_equal(np.asarray(state.objs),
                                  np.asarray(whole.state.objs))
    assert whole.n_dispatches == 2  # init + ONE scan for all 7 generations

    # checkpoint chunking (3+3+1) must not change the arithmetic either
    import tempfile
    with tempfile.TemporaryDirectory() as out:
        chunked = search.run_search(prob, backend="reference", pop_size=10,
                                    n_generations=7, seed=0, out_dir=out,
                                    checkpoint_every=3)
    np.testing.assert_array_equal(np.asarray(state.genes),
                                  np.asarray(chunked.state.genes))
    assert chunked.n_dispatches == 4  # init + chunks of 3, 3, 1


def test_resume_from_off_boundary_save_realigns(tree_setup, tmp_path):
    """Kill after an off-boundary final save: resume restores mid-interval,
    realigns at the next checkpoint_every multiple, and the end state is
    bit-identical to the uninterrupted run."""
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    out = str(tmp_path / "run")
    cfg = search.SearchConfig(pop_size=8, n_generations=7, out_dir=out,
                              checkpoint_every=3)
    full = search.run_search(prob, cfg)
    import shutil
    shutil.rmtree(out)
    # "killed" at generation 4: saves land at 3 (boundary) and 4 (final)
    search.run_search(prob, cfg, n_generations=4)
    from repro.runtime import checkpoint
    assert checkpoint.latest_step(out + "/ckpt") == 4
    resumed = search.run_search(prob, cfg, resume=True)
    np.testing.assert_array_equal(np.asarray(full.state.genes),
                                  np.asarray(resumed.state.genes))
    np.testing.assert_array_equal(full.pareto_objs, resumed.pareto_objs)


def test_islands_checkpoint_resume_roundtrip(tree_setup, tmp_path):
    """Islands state round-trips through runtime.checkpoint: a run killed
    mid-way and resumed ends bit-identical to the uninterrupted run."""
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    out = str(tmp_path / "islands")
    cfg = search.SearchConfig(backend="islands", pop_size=16,
                              n_generations=6, migrate_every=2,
                              checkpoint_every=2, out_dir=out, seed=3)
    full = search.run_search(prob, cfg)
    import shutil
    shutil.rmtree(out)
    partial = search.run_search(prob, cfg, n_generations=2)
    assert partial.n_dispatches >= 2
    resumed = search.run_search(prob, cfg, resume=True)
    np.testing.assert_array_equal(np.asarray(full.state.genes),
                                  np.asarray(resumed.state.genes))
    np.testing.assert_array_equal(np.asarray(full.state.objs),
                                  np.asarray(resumed.state.objs))
    np.testing.assert_array_equal(full.pareto_objs, resumed.pareto_objs)


def test_resume_rejects_mismatched_driver_family(tree_setup, tmp_path):
    """An islands checkpoint must not silently restore into the single-state
    engine (and vice versa) — the manifest meta makes it a clear error."""
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    out = str(tmp_path / "family")
    search.run_search(prob, backend="islands", pop_size=16, n_generations=2,
                      migrate_every=2, checkpoint_every=2, out_dir=out)
    with pytest.raises(ValueError, match="islands"):
        search.run_search(prob, backend="reference", pop_size=16,
                          n_generations=4, checkpoint_every=2, out_dir=out,
                          resume=True)


def test_checkpoint_every_without_out_dir_stays_single_dispatch(tree_setup):
    """With nowhere to save, checkpoint_every must not shrink the chunks."""
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    r = search.run_search(prob, backend="reference", pop_size=8,
                          n_generations=6, checkpoint_every=2)
    assert r.n_dispatches == 2  # init + ONE scan for all 6 generations


def test_chunk_schedule_rejects_negative_interval():
    from repro.search.engine import _chunk_schedule
    with pytest.raises(ValueError, match="checkpoint_every"):
        _chunk_schedule(0, 5, -1)


def test_resume_rejects_pop_size_mismatch(tree_setup, tmp_path):
    """A clear error, not a shape assert, when the population changed."""
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    out = str(tmp_path / "pop")
    search.run_search(prob, backend="reference", pop_size=8, n_generations=2,
                      checkpoint_every=2, out_dir=out)
    with pytest.raises(ValueError, match="pop_size"):
        search.run_search(prob, backend="reference", pop_size=16,
                          n_generations=4, checkpoint_every=2, out_dir=out,
                          resume=True)


def test_negative_checkpoint_every_rejected_all_backends(tree_setup):
    ds, tree, pt = tree_setup
    prob = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    for backend in ("reference", "islands"):
        with pytest.raises(ValueError, match="checkpoint_every"):
            search.run_search(prob, backend=backend, pop_size=8,
                              n_generations=2, checkpoint_every=-3)
