"""Area model: exact constant-propagated comparator gate counts + LUT."""
import numpy as np
from hypothesis import given, strategies as st

from repro.core import area


def test_gate_count_edge_cases():
    for p in range(2, 9):
        # X > 2^p - 1 is constant false: zero gates
        assert area.comparator_gate_counts((1 << p) - 1, p) == (0, 0)
        # X > 0 is an OR-tree over all bits: p - 1 OR gates
        assert area.comparator_gate_counts(0, p) == (0, p - 1)
        # X > 2^(p-1) - 1  <=>  MSB set: free
        assert area.comparator_gate_counts((1 << (p - 1)) - 1, p) == (0, 0)


@given(p=st.integers(2, 8), t=st.integers(0, 255))
def test_gate_count_matches_formula(p, t):
    t = t % (1 << p)
    n_and, n_or = area.comparator_gate_counts(t, p)
    u = t + 1
    if u >= (1 << p):
        assert (n_and, n_or) == (0, 0)
    else:
        tz = (u & -u).bit_length() - 1
        assert n_and + n_or == p - 1 - tz
        assert n_and == bin(u >> (tz + 1)).count("1")


@given(p=st.integers(2, 8), t=st.integers(0, 255))
def test_gate_count_vs_truth_table_synthesis(p, t):
    """Oracle: evaluate the counted netlist semantics — a chain with exactly
    (n_and + n_or) binary gates computes X > t — by brute force over all X."""
    t = t % (1 << p)
    xs = np.arange(1 << p)
    want = xs > t
    # reconstruct the chain: g = True; LSB..MSB of u
    u = t + 1
    if u >= (1 << p):
        assert (xs > t).sum() == 0  # constant-false netlist is correct
        return
    g = np.ones(1 << p, dtype=bool)
    for i in range(p):
        xi = (xs >> i) & 1
        if (u >> i) & 1:
            g = (xi == 1) & g
        else:
            g = (xi == 1) | g
    np.testing.assert_array_equal(g, want)


def test_lut_shape_and_indexing():
    # since DESIGN.md §16 the LUT spans p in [0, MAX_BITS]: truncation can
    # shrink effective width below MIN_BITS, down to the 0-bit const-false
    lut, off = area.build_area_lut()
    assert lut.shape[0] == sum(1 << p for p in range(0, 9))
    np.testing.assert_array_equal(off[:3], [0, 1, 3])
    # LUT at (p=8, t) equals direct model
    for t in [0, 1, 127, 128, 200, 255]:
        assert lut[off[8] + t] == np.float32(area.comparator_area_mm2(t, 8))
    # sub-MIN_BITS rows are all-zero (0/1-bit greater-than needs no gates)
    assert lut[off[0]] == 0.0
    assert (lut[off[1]: off[1] + 2] == 0.0).all()
    # lower precision is never more expensive than 8-bit on average
    mean8 = lut[off[8]: off[8] + 256].mean()
    mean2 = lut[off[2]: off[2] + 4].mean()
    assert mean2 < mean8


def test_area_nonlinearity_valleys():
    """Fig. 4 character: valleys at t = 2^k - 1, sawtooth odd/even."""
    a = np.array([area.comparator_area_mm2(t, 8) for t in range(256)])
    assert a[127] == 0.0                      # X>127 == MSB
    assert a[63] < a[62] and a[63] < a[64]    # valley at 2^6-1
    assert (a[1::2] <= a[0::2]).mean() > 0.9  # odd thresholds cheaper


def test_power_model_matches_paper_slope():
    # paper Table I rows are consistent with ~0.0455 mW/mm^2
    paper = [(162.50, 7.55), (68.04, 3.11), (178.63, 8.12), (551.08, 26.10),
             (98.75, 4.47), (574.46, 25.00), (513.84, 22.30), (30.13, 1.43),
             (57.70, 2.68), (543.12, 23.20)]
    for a, p in paper:
        assert abs(area.power_mw(a) - p) / p < 0.08
