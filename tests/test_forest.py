"""Random-Forest extension: voting, joint approximation, cross-tree CSE."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.datasets import load_dataset, quantize_u8
from repro.core import forest as F, nsga2, quant


def _setup():
    ds = load_dataset("seeds")
    fr = F.train_forest(ds.x_train, ds.y_train, ds.n_classes, n_trees=3)
    return ds, fr


def test_forest_beats_or_matches_single_tree_accuracy():
    ds, fr = _setup()
    x8 = jnp.asarray(quantize_u8(ds.x_test).astype(np.int32))
    bits = jnp.full((fr.n_comparators,), 8, jnp.int32)
    marg = jnp.zeros((fr.n_comparators,), jnp.int32)
    pred = F.forest_predict(fr, x8, bits, marg)
    acc = float(jnp.mean((pred == jnp.asarray(ds.y_test)).astype(jnp.float32)))
    assert acc > 0.75  # sanity: voting works


def test_cross_tree_cse_saves_area():
    """Snapping all trees to 2-bit grids forces shared comparators: the
    dedup'd forest area must undercut the additive sum."""
    _, fr = _setup()
    bits = np.full(fr.n_comparators, 2)
    marg = np.zeros(fr.n_comparators, dtype=int)
    dedup = F.forest_area_mm2(fr, bits, marg, dedup=True)
    additive = F.forest_area_mm2(fr, bits, marg, dedup=False)
    assert dedup < additive


def test_forest_nsga2_finds_reductions():
    ds, fr = _setup()
    fit, exact_acc, exact_area = F.make_forest_fitness(fr, ds.x_test, ds.y_test)
    cfg = nsga2.NSGA2Config(pop_size=24, n_generations=10)
    state = nsga2.run(jax.random.PRNGKey(0), fit, fr.n_genes, cfg,
                      seed_genes=quant.exact_tree_genes(fr.n_comparators))
    objs, _ = nsga2.pareto_front(state.objs, state.genes)
    ok = objs[objs[:, 0] <= 0.01 + 1e-9]
    assert len(ok) > 0
    assert ok[:, 1].min() < 0.9  # >1.1x area reduction at <=1% loss
