"""Multi-device behaviours (8 host devices via subprocess: XLA_FLAGS must be
set before jax init, so these run in a fresh interpreter).

Covers: island-model GA with ring migration, sharded population fitness,
int8 compressed cross-group psum, elastic checkpoint restore onto a
different mesh, and the sharded LM train step (the production train path in
miniature). A second suite covers the mesh-sharded NSGA-II (DESIGN.md §13):
hierarchical domination vs the monolithic oracle, per-shard kernel routing
on LOCAL rows, sharded crowding vs the sequential-loop oracle, sharded
chunks bit-exact vs `nsga2.make_chunk` on tree / forest / inert-padded
sweep problems above and below DOMINATION_KERNEL_MIN_POP, and an island
checkpoint resumed onto a mesh of entirely different devices."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
assert len(jax.devices()) == 8

# --- island GA + sharded fitness -------------------------------------------
from repro.datasets import load_dataset
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.core import approx, dist, nsga2

ds = load_dataset("seeds")
tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
pt = to_parallel(tree)
prob = approx.build_problem(pt, ds.x_test, ds.y_test)
fit_vm = lambda g: jax.vmap(lambda x: approx.objectives(prob, x))(g)

mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
sf = dist.sharded_fitness(fit_vm, mesh)
g = jax.random.uniform(jax.random.PRNGKey(0), (64, prob.n_genes))
g = jax.device_put(g, NamedSharding(mesh, P("data")))
o_sharded = np.asarray(sf(g))
o_ref = np.asarray(fit_vm(g))
assert np.allclose(o_sharded, o_ref, atol=1e-6), "sharded fitness != local"

cfg = dist.IslandConfig(local_pop=16, migrate_every=2, n_migrate=2)
st = dist.run_islands(jax.random.PRNGKey(1), fit_vm, prob.n_genes, mesh, cfg,
                      n_rounds=3)
objs, genes = dist.gathered_pareto(st)
assert (objs[:, 1] < 1.0).any(), "islands found no area reduction"
print("ISLANDS_OK", len(objs))

# --- compressed cross-group psum --------------------------------------------
from repro.optim import compress
from functools import partial
from jax.experimental.shard_map import shard_map

mesh2 = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
x = jnp.arange(32.0).reshape(2, 16) / 7.0

@partial(shard_map, mesh=mesh2, in_specs=(P("pod", None),), out_specs=P("pod", None),
         check_rep=False)
def mean_pods(g):
    return compress.compressed_psum({"g": g}, "pod")["g"]

got = np.asarray(mean_pods(x))
want = np.broadcast_to(x.mean(axis=0, keepdims=True), x.shape)
err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
assert err < 0.02, f"compressed psum err {err}"
print("COMPRESS_OK", err)

# --- elastic checkpoint restore ---------------------------------------------
from repro.runtime import checkpoint
import tempfile
tree8 = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                             NamedSharding(mesh, P("data", None)))}
with tempfile.TemporaryDirectory() as td:
    checkpoint.save(td, 3, tree8)
    mesh4 = Mesh(np.array(jax.devices()[:4]).reshape(4), ("data",))
    shard4 = {"w": NamedSharding(mesh4, P(None, "data"))}
    restored, step = checkpoint.restore(td, 3, tree8, shardings=shard4)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64.0).reshape(8, 8))
    assert restored["w"].sharding.mesh.shape["data"] == 4
print("ELASTIC_OK")

# --- sharded LM train step (production path in miniature) -------------------
import dataclasses
from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.runtime import train as train_rt
from repro.optim import get_optimizer
from repro.sharding import params as sp
from repro.sharding.rules import MeshRules

mesh3 = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("pod", "data", "model"))
rules = MeshRules(tp=2, batch=("pod", "data"), expert=("pod", "data"),
                  ff_wide=("pod", "data", "model"))
cfg = reduced_config(get_config("minitron-8b"), n_heads=4, n_kv_heads=2,
                     d_model=64, d_ff=128)
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
pspecs = sp.param_specs(cfg, rules, mesh3)
params = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh3, s)),
                      params, pspecs)
opt = get_optimizer(cfg)
state = train_rt.init_train_state(params, opt)
step_fn = jax.jit(train_rt.make_train_step(cfg, rules=rules, optimizer=opt))
tok = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": jax.device_put(tok, NamedSharding(mesh3, P(("pod", "data"), None)))}
with mesh3:  # with_sharding_constraint(PartitionSpec) needs an ambient mesh
    state, metrics = step_fn(state, batch)
    loss1 = float(metrics["loss"])
    state, metrics = step_fn(state, batch)
assert np.isfinite(loss1) and float(metrics["loss"]) < loss1 + 1.0
print("SHARDED_TRAIN_OK", loss1, float(metrics["loss"]))
print("ALL_MULTIDEVICE_OK")
"""


SCRIPT_SHARDED = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
assert len(jax.devices()) == 8

from repro.datasets import load_dataset
from repro.core import dist, forest as forest_mod, nsga2
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.kernels import ops as kops
from repro.launch.mesh import make_search_mesh
from repro.runtime import checkpoint
from repro import search
from repro.search import sweep as sweep_mod

mesh4 = make_search_mesh("4", axes=("pop",))
key = jax.random.PRNGKey(0)

# --- hierarchical domination sort == monolithic oracle (jnp routing) --------
for p, m in ((64, 2), (128, 3), (256, 2)):
    objs = jax.random.uniform(jax.random.fold_in(key, p), (p, m))
    np.testing.assert_array_equal(
        np.asarray(dist.sharded_non_dominated_sort(objs, mesh4)),
        np.asarray(nsga2.non_dominated_sort(objs)),
        err_msg=f"hier sort p={p}")
print("HIER_SORT_OK")

# --- sharded crowding == the sequential-loop oracle (bit-exact) -------------
def loop_crowding(objs, rank):
    p, m = objs.shape
    out = jnp.zeros((p,), dtype=jnp.float32)
    for k in range(m):
        v = objs[:, k]
        order = jnp.argsort(rank.astype(jnp.float32) * nsga2._BIG + v)
        v_s, r_s = v[order], rank[order]
        prev_ok = jnp.concatenate([jnp.array([False]), r_s[1:] == r_s[:-1]])
        next_ok = jnp.concatenate([r_s[:-1] == r_s[1:], jnp.array([False])])
        v_prev = jnp.concatenate([v_s[:1], v_s[:-1]])
        v_next = jnp.concatenate([v_s[1:], v_s[-1:]])
        fmin = jnp.full((p,), jnp.inf).at[r_s].min(v_s)
        fmax = jnp.full((p,), -jnp.inf).at[r_s].max(v_s)
        span = jnp.maximum((fmax - fmin)[r_s], 1e-12)
        d = jnp.where(prev_ok & next_ok, (v_next - v_prev) / span, jnp.inf)
        out = out.at[order].add(jnp.where(jnp.isinf(d), nsga2._BIG, d))
    return out

objs = jax.random.uniform(jax.random.fold_in(key, 99), (128, 2))
rank = nsga2.non_dominated_sort(objs)
np.testing.assert_array_equal(
    np.asarray(dist.sharded_crowding_distance(objs, rank, mesh4)),
    np.asarray(loop_crowding(objs, rank)))
print("CROWD_OK")

# --- kernel routing decides on LOCAL (post-shard) rows ----------------------
# Oracle ranks first (default jnp routing), then force the kernel available
# (interpret mode off-TPU) with a lowered threshold: p=128 shards to 32 local
# rows (stays jnp), p=256 shards to 64 (engages the kernel) — both bit-exact.
oracle = {}
for p in (128, 256):
    o = jax.random.uniform(jax.random.fold_in(key, 1000 + p), (p, 2))
    oracle[p] = (o, np.asarray(nsga2.non_dominated_sort(o)))
orig_min = nsga2.DOMINATION_KERNEL_MIN_POP
orig_avail = nsga2._kernel_domination_available
real_block = kops.domination_block_bool
nsga2.DOMINATION_KERNEL_MIN_POP = 64
nsga2._kernel_domination_available = lambda: True
calls = []
kops.domination_block_bool = (
    lambda a, b, **kw: calls.append((a.shape[0], b.shape[0]))
    or real_block(a, b, **kw))
jax.clear_caches()
for p in (128, 256):
    o, want = oracle[p]
    np.testing.assert_array_equal(
        np.asarray(dist.sharded_non_dominated_sort(o, mesh4)), want,
        err_msg=f"kernel-routed sort p={p}")
assert (32, 128) not in calls, f"32-row shard must stay jnp: {calls}"
assert (64, 256) in calls, f"64-row shard must engage the kernel: {calls}"
print("ROUTING_OK", sorted(set(calls)))

# --- sharded chunk == nsga2.make_chunk, tree/forest, above+below min-pop ----
ds = load_dataset("seeds")
pt = to_parallel(train_tree(ds.x_train, ds.y_train, ds.n_classes))
prob_tree = search.build_tree_problem(pt, ds.x_test, ds.y_test)
forest = forest_mod.train_forest(ds.x_train, ds.y_train, ds.n_classes,
                                 n_trees=2)
prob_forest = search.build_forest_problem(forest, ds.x_test, ds.y_test)

def check_chunk(prob, pop, gens, tag):
    fit = search.make_fitness(prob, "reference")
    cfg = nsga2.NSGA2Config(pop_size=pop, n_generations=gens)
    st0 = nsga2.init_state(jax.random.PRNGKey(7), fit, prob.n_genes, cfg)
    want = jax.jit(nsga2.make_chunk(fit, cfg, gens))(st0)
    st = jax.tree.map(jax.device_put, st0, dist.sharded_state_sharding(mesh4))
    got = dist.make_sharded_chunk(fit, mesh4, cfg, gens)(st)
    for f in ("genes", "objs", "rank", "crowd", "key", "generation"):
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f)),
                                      err_msg=f"{tag}.{f}")

# threshold still patched to 64: the pool's 128 local rows run the kernel
check_chunk(prob_tree, 256, 2, "tree-kernel-routed")
kops.domination_block_bool = real_block
nsga2.DOMINATION_KERNEL_MIN_POP = orig_min
nsga2._kernel_domination_available = orig_avail
jax.clear_caches()
check_chunk(prob_tree, 64, 3, "tree-below-minpop")
check_chunk(prob_tree, 1024, 2, "tree-above-minpop")  # pool 2048 > 512
check_chunk(prob_forest, 64, 2, "forest")
print("CHUNK_OK")

# --- inert-padded sweep bucket on a 2x4 (bucket, pop) mesh ------------------
ds2 = load_dataset("balance")
pt2 = to_parallel(train_tree(ds2.x_train, ds2.y_train, ds2.n_classes))
problems = {"seeds": prob_tree,
            "balance": search.build_tree_problem(pt2, ds2.x_test, ds2.y_test)}
scfg = dict(pop_size=16, n_generations=4, seed=0, max_buckets=1)
s_ref = sweep_mod.run_sweep(problems, sweep_mod.SweepConfig(**scfg))
s_mesh = sweep_mod.run_sweep(problems, sweep_mod.SweepConfig(mesh="2x4",
                                                             **scfg))
for name in problems:
    a, b = s_ref.results[name], s_mesh.results[name]
    np.testing.assert_array_equal(np.asarray(a.state.genes),
                                  np.asarray(b.state.genes), err_msg=name)
    np.testing.assert_array_equal(a.pareto_objs, b.pareto_objs, err_msg=name)
print("SWEEP_MESH_OK")

# --- engine e2e: --mesh run == single-device oracle run ---------------------
rcfg = dict(pop_size=32, n_generations=6, seed=3)
r_ref = search.run_search(prob_tree, search.SearchConfig(**rcfg))
r_mesh = search.run_search(prob_tree, search.SearchConfig(mesh="4", **rcfg))
for name in ("genes", "objs", "rank", "crowd"):
    np.testing.assert_array_equal(np.asarray(getattr(r_ref.state, name)),
                                  np.asarray(getattr(r_mesh.state, name)),
                                  err_msg=f"engine {name}")
np.testing.assert_array_equal(r_ref.pareto_objs, r_mesh.pareto_objs)
print("ENGINE_MESH_OK", r_mesh.n_dispatches)

# --- island checkpoint resumed onto a mesh of different devices -------------
fit = search.make_fitness(prob_tree, "reference")
icfg = dist.IslandConfig(local_pop=16, migrate_every=2, n_migrate=2)
devs = jax.devices()
mesh_a = Mesh(np.array(devs[:4]).reshape(4), ("data",))
mesh_b = Mesh(np.array(devs[4:]).reshape(4), ("data",))
st0 = dist.init_islands(jax.random.PRNGKey(5), fit, prob_tree.n_genes,
                        mesh_a, icfg)
chunk_a = dist.make_island_chunk(fit, mesh_a, icfg, 2)
mid = chunk_a(st0)
want = chunk_a(mid)  # uninterrupted continuation on mesh A
with tempfile.TemporaryDirectory() as td:
    checkpoint.save(td, 2, mid)
    restored, step = checkpoint.restore(
        td, 2, jax.device_get(mid),
        shardings=dist.island_state_sharding(mesh_b))
assert step == 2
got = dist.make_island_chunk(fit, mesh_b, icfg, 2)(restored)
used = {d for a in jax.tree.leaves(got) for d in a.devices()}
assert used <= set(devs[4:]), f"resumed run not on the new mesh: {used}"
for f in ("genes", "objs", "rank", "crowd", "key", "generation"):
    np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                  np.asarray(getattr(want, f)),
                                  err_msg=f"resharded islands {f}")
print("RESHARD_OK")
print("ALL_SHARDED_OK")
"""


def _run_subprocess_suite(script, sentinel):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert sentinel in res.stdout, res.stdout[-3000:]


@pytest.mark.slow
def test_multidevice_suite():
    _run_subprocess_suite(SCRIPT, "ALL_MULTIDEVICE_OK")


@pytest.mark.slow
def test_sharded_search_suite():
    _run_subprocess_suite(SCRIPT_SHARDED, "ALL_SHARDED_OK")
