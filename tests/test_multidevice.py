"""Multi-device behaviours (8 host devices via subprocess: XLA_FLAGS must be
set before jax init, so these run in a fresh interpreter).

Covers: island-model GA with ring migration, sharded population fitness,
int8 compressed cross-group psum, elastic checkpoint restore onto a
different mesh, and the sharded LM train step (the production train path in
miniature)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
assert len(jax.devices()) == 8

# --- island GA + sharded fitness -------------------------------------------
from repro.datasets import load_dataset
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.core import approx, dist, nsga2

ds = load_dataset("seeds")
tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
pt = to_parallel(tree)
prob = approx.build_problem(pt, ds.x_test, ds.y_test)
fit_vm = lambda g: jax.vmap(lambda x: approx.objectives(prob, x))(g)

mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
sf = dist.sharded_fitness(fit_vm, mesh)
g = jax.random.uniform(jax.random.PRNGKey(0), (64, prob.n_genes))
g = jax.device_put(g, NamedSharding(mesh, P("data")))
o_sharded = np.asarray(sf(g))
o_ref = np.asarray(fit_vm(g))
assert np.allclose(o_sharded, o_ref, atol=1e-6), "sharded fitness != local"

cfg = dist.IslandConfig(local_pop=16, migrate_every=2, n_migrate=2)
st = dist.run_islands(jax.random.PRNGKey(1), fit_vm, prob.n_genes, mesh, cfg,
                      n_rounds=3)
objs, genes = dist.gathered_pareto(st)
assert (objs[:, 1] < 1.0).any(), "islands found no area reduction"
print("ISLANDS_OK", len(objs))

# --- compressed cross-group psum --------------------------------------------
from repro.optim import compress
from functools import partial
from jax.experimental.shard_map import shard_map

mesh2 = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
x = jnp.arange(32.0).reshape(2, 16) / 7.0

@partial(shard_map, mesh=mesh2, in_specs=(P("pod", None),), out_specs=P("pod", None),
         check_rep=False)
def mean_pods(g):
    return compress.compressed_psum({"g": g}, "pod")["g"]

got = np.asarray(mean_pods(x))
want = np.broadcast_to(x.mean(axis=0, keepdims=True), x.shape)
err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
assert err < 0.02, f"compressed psum err {err}"
print("COMPRESS_OK", err)

# --- elastic checkpoint restore ---------------------------------------------
from repro.runtime import checkpoint
import tempfile
tree8 = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                             NamedSharding(mesh, P("data", None)))}
with tempfile.TemporaryDirectory() as td:
    checkpoint.save(td, 3, tree8)
    mesh4 = Mesh(np.array(jax.devices()[:4]).reshape(4), ("data",))
    shard4 = {"w": NamedSharding(mesh4, P(None, "data"))}
    restored, step = checkpoint.restore(td, 3, tree8, shardings=shard4)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64.0).reshape(8, 8))
    assert restored["w"].sharding.mesh.shape["data"] == 4
print("ELASTIC_OK")

# --- sharded LM train step (production path in miniature) -------------------
import dataclasses
from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.runtime import train as train_rt
from repro.optim import get_optimizer
from repro.sharding import params as sp
from repro.sharding.rules import MeshRules

mesh3 = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("pod", "data", "model"))
rules = MeshRules(tp=2, batch=("pod", "data"), expert=("pod", "data"),
                  ff_wide=("pod", "data", "model"))
cfg = reduced_config(get_config("minitron-8b"), n_heads=4, n_kv_heads=2,
                     d_model=64, d_ff=128)
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
pspecs = sp.param_specs(cfg, rules, mesh3)
params = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh3, s)),
                      params, pspecs)
opt = get_optimizer(cfg)
state = train_rt.init_train_state(params, opt)
step_fn = jax.jit(train_rt.make_train_step(cfg, rules=rules, optimizer=opt))
tok = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": jax.device_put(tok, NamedSharding(mesh3, P(("pod", "data"), None)))}
with mesh3:  # with_sharding_constraint(PartitionSpec) needs an ambient mesh
    state, metrics = step_fn(state, batch)
    loss1 = float(metrics["loss"])
    state, metrics = step_fn(state, batch)
assert np.isfinite(loss1) and float(metrics["loss"]) < loss1 + 1.0
print("SHARDED_TRAIN_OK", loss1, float(metrics["loss"]))
print("ALL_MULTIDEVICE_OK")
"""


@pytest.mark.slow
def test_multidevice_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert "ALL_MULTIDEVICE_OK" in res.stdout
