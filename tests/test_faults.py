"""Stuck-at fault injection suite (DESIGN.md §17).

Pins `core.faults` to its two oracles and the campaign layer to its schema:

  - the zero-fault (empty mask) lane is bit-identical to
    `core.netlist.simulate` on a tree, a K>1 forest under BOTH vote-adder
    modes, and an MLP MAC circuit;
  - the exhaustive single stuck-at campaign matches the serial per-gate
    Python oracle array-for-array on the same circuit zoo;
  - Monte-Carlo campaigns reproduce bit-for-bit under a fixed seed and
    move under a different one;
  - `fault_report.json` round-trips its validator; missing/unknown keys at
    every nesting level raise named `ValueError`s (never bare `KeyError`);
  - the `faults` and `serve` CLIs exit 2 with a one-line error on
    missing/truncated artifacts.
"""
from __future__ import annotations

import copy
import json

import numpy as np
import pytest

from repro import search
from repro.core import faults, netlist, quant
from repro.core.forest import train_forest
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.datasets import load_dataset, quantize_u8
from repro.search import robustness
from repro.search.__main__ import faults_main, serve_main

N_VECTORS = 32   # test-split slice driving the bit-exactness differentials


def _tree_circuit(dataset="seeds", n_trees=1, vote_adder="exact"):
    ds = load_dataset(dataset)
    if n_trees <= 1:
        ptrees = [to_parallel(train_tree(ds.x_train, ds.y_train,
                                         ds.n_classes))]
    else:
        ptrees = train_forest(ds.x_train, ds.y_train, ds.n_classes,
                              n_trees=n_trees).ptrees
    n = sum(p.n_comparators for p in ptrees)
    rng = np.random.default_rng(hash((dataset, n_trees)) % 2**32)
    bits = rng.integers(quant.MIN_BITS, quant.MAX_BITS + 1, n)
    thr = np.concatenate([p.threshold for p in ptrees])
    t_int = np.asarray(quant.threshold_to_int(thr, bits))
    circuit = netlist.build_circuit(ptrees, bits, t_int, ds.n_classes,
                                    vote_adder=vote_adder)
    x8 = quantize_u8(ds.x_test)[:N_VECTORS]
    y = np.asarray(ds.y_test[:N_VECTORS], np.int64)
    return circuit, x8, y


def _mlp_circuit():
    """A tiny integer MLP netlist — small enough for the serial oracle."""
    rng = np.random.default_rng(3)
    f, h, c = 3, 4, 3
    w1 = rng.integers(-3, 4, (f, h))
    w2 = rng.integers(-3, 4, (h, c))
    circuit = netlist.build_mlp_circuit(w1, w2, 4, c)
    x8 = quantize_u8(rng.uniform(0, 1, (N_VECTORS, f)).astype(np.float32))
    y = rng.integers(0, c, N_VECTORS).astype(np.int64)
    return circuit, x8, y


CIRCUITS = {
    "tree": lambda: _tree_circuit("seeds", 1),
    "forest_exact": lambda: _tree_circuit("vertebral", 3, "exact"),
    "forest_approx": lambda: _tree_circuit("vertebral", 3, "approx"),
    "mlp": _mlp_circuit,
}


@pytest.fixture(scope="module", params=sorted(CIRCUITS))
def circuit_case(request):
    circuit, x8, y = CIRCUITS[request.param]()
    return request.param, circuit, x8, y


# --- site enumeration ------------------------------------------------------

def test_sites_cover_every_non_const_gate(circuit_case):
    """Sites = all INPUT + logic gates, in gate-id order, constants never."""
    _, circuit, _, _ = circuit_case
    sites = faults.enumerate_fault_sites(circuit)
    op = np.asarray(circuit.op)
    expect = np.flatnonzero(op >= netlist.INPUT)
    assert [s.gate for s in sites] == expect.tolist()
    for s in sites:
        assert s.kind == ("input" if op[s.gate] == netlist.INPUT else "gate")
        if s.kind == "input":
            assert s.label == f"input[f{s.feature}.b{s.bit}]"
            assert (s.feature, s.bit) == (int(circuit.a[s.gate]),
                                          int(circuit.b[s.gate]))
        else:
            assert s.label.endswith(f"@{s.gate}")


def test_single_fault_lanes_pair_every_site():
    circuit, _, _ = _tree_circuit()
    gates, values = faults.single_fault_lanes(circuit)
    sites = faults.enumerate_fault_sites(circuit)
    assert len(gates) == 2 * len(sites)
    assert gates[::2].tolist() == gates[1::2].tolist()
    assert values[::2].tolist() == [0] * len(sites)
    assert values[1::2].tolist() == [1] * len(sites)


# --- the two oracle pins ---------------------------------------------------

def test_zero_fault_bit_identical_to_simulate(circuit_case):
    """Acceptance: the empty-mask lane IS `netlist.simulate`, bit for bit,
    on trees, forests (both vote adders), and MLP MAC circuits."""
    _, circuit, x8, _ = circuit_case
    sim = faults.FaultSimulator(circuit)
    np.testing.assert_array_equal(
        sim.run_zero_fault(x8), np.asarray(netlist.simulate(circuit, x8)))


def test_exhaustive_single_stuck_at_matches_serial_oracle(circuit_case):
    """Acceptance: every (site, polarity) lane of the vmapped campaign
    equals the serial per-gate Python oracle, array for array."""
    _, circuit, x8, _ = circuit_case
    sim = faults.FaultSimulator(circuit)
    gates, values = faults.single_fault_lanes(circuit)
    preds = sim.run_sites(x8, gates, values, chunk=17)  # pad-and-crop path
    assert preds.shape == (len(gates), x8.shape[0])
    for i in range(len(gates)):
        serial = faults.simulate_faulty_serial(
            circuit, x8, [(gates[i], values[i])])
        np.testing.assert_array_equal(preds[i], serial, err_msg=(
            f"lane {i}: gate {gates[i]} stuck-at-{values[i]}"))


def test_serial_oracle_zero_fault_matches_simulate(circuit_case):
    _, circuit, x8, _ = circuit_case
    np.testing.assert_array_equal(
        faults.simulate_faulty_serial(circuit, x8),
        np.asarray(netlist.simulate(circuit, x8)))


def test_multi_fault_mask_matches_serial_oracle():
    """Multi-hot masks (the Monte-Carlo shape) agree with the serial oracle
    applying the same fault set."""
    circuit, x8, _ = _tree_circuit()
    sites = faults.enumerate_fault_sites(circuit)
    rng = np.random.default_rng(7)
    sim = faults.FaultSimulator(circuit)
    for trial in range(4):
        chosen = rng.choice(len(sites), size=3, replace=False)
        vals = rng.integers(0, 2, 3)
        mask = np.zeros((1, circuit.n_gates), bool)
        val = np.zeros((1, circuit.n_gates), bool)
        pairs = []
        for s, v in zip(chosen, vals):
            mask[0, sites[s].gate] = True
            val[0, sites[s].gate] = bool(v)
            pairs.append((sites[s].gate, int(v)))
        np.testing.assert_array_equal(
            sim.run_masks(x8, mask, val)[0],
            faults.simulate_faulty_serial(circuit, x8, pairs))


def test_run_masks_shape_validation():
    circuit, x8, _ = _tree_circuit()
    sim = faults.FaultSimulator(circuit)
    bad = np.zeros((2, circuit.n_gates + 1), bool)
    with pytest.raises(ValueError, match="stuck masks must be"):
        sim.run_masks(x8, bad, bad)
    good = np.zeros((2, circuit.n_gates), bool)
    with pytest.raises(ValueError, match="do not match"):
        sim.run_masks(x8, good, np.zeros((3, circuit.n_gates), bool))


def test_chunking_is_invisible():
    """Any chunk size — 1, prime, larger than the lane count — returns the
    identical campaign (padding lanes are cropped, never leaked)."""
    circuit, x8, _ = _tree_circuit()
    sim = faults.FaultSimulator(circuit)
    gates, values = faults.single_fault_lanes(circuit)
    ref = sim.run_sites(x8, gates, values, chunk=len(gates))
    for chunk in (1, 13, len(gates) + 100):
        np.testing.assert_array_equal(
            ref, sim.run_sites(x8, gates, values, chunk=chunk))


# --- campaign metrics ------------------------------------------------------

def test_monte_carlo_reproducible_under_fixed_seed():
    circuit, x8, y = _tree_circuit()
    sim = faults.FaultSimulator(circuit)
    a = robustness.monte_carlo(sim, x8, y, n_trials=8, seed=11)
    b = robustness.monte_carlo(sim, x8, y, n_trials=8, seed=11)
    np.testing.assert_array_equal(a.pop("_accuracies"), b.pop("_accuracies"))
    assert a == b
    c = robustness.monte_carlo(sim, x8, y, n_trials=8, seed=12)
    assert not np.array_equal(b and 0, c.pop("_accuracies"))  # different draw


def test_critical_gates_ranked_by_drop():
    circuit, x8, y = _tree_circuit()
    sim = faults.FaultSimulator(circuit)
    sites, accs = robustness.single_stuck_at(sim, x8, y)
    baseline = float((sim.run_zero_fault(x8) == y).mean())
    ranked = robustness.critical_gates(sites, accs, baseline, top_k=5)
    drops = [r["drop"] for r in ranked]
    assert drops == sorted(drops, reverse=True)
    assert len(ranked) == 5
    worst = ranked[0]
    per_site = baseline - np.asarray(accs).reshape(-1, 2).min(axis=1)
    assert worst["drop"] == pytest.approx(per_site.max())
    assert worst["stuck_value"] in (0, 1)


def test_point_robustness_invariants():
    circuit, x8, y = _tree_circuit()
    row = robustness.point_robustness(circuit, x8, y, n_trials=4)
    assert row["zero_fault_matches_simulate"] is True
    assert row["n_faults"] == 2 * row["n_sites"]
    sf = row["single_fault"]
    assert sf["worst_accuracy"] <= sf["mean_accuracy"]
    assert sf["worst_drop"] == pytest.approx(
        row["baseline_accuracy"] - sf["worst_accuracy"])


# --- fault_report.json schema discipline -----------------------------------

@pytest.fixture(scope="module")
def tree_report(tmp_path_factory):
    """A real campaign payload from a tiny seeds search (any family path
    would do — the schema is family-agnostic)."""
    ds = load_dataset("seeds")
    pt = to_parallel(train_tree(ds.x_train, ds.y_train, ds.n_classes))
    problem = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    out = str(tmp_path_factory.mktemp("faults") / "run")
    cfg = search.SearchConfig(pop_size=8, n_generations=2, seed=0,
                              dataset="seeds", out_dir=out)
    search.run_search(problem, cfg)
    artifact = search.load_pareto_artifact(out + "/pareto.json")
    x8 = quantize_u8(ds.x_test)[:N_VECTORS]
    y = np.asarray(ds.y_test[:N_VECTORS])
    payload = robustness.run_campaign(artifact, x8, y, source="pareto.json",
                                      point="all", n_trials=4)
    return payload, out


def test_fault_report_roundtrip(tree_report, tmp_path):
    """Acceptance: write -> load -> identical payload, validated twice."""
    payload, _ = tree_report
    path = str(tmp_path / "fault_report.json")
    robustness.write_fault_report(payload, path)
    assert robustness.load_fault_report(path) == json.loads(
        json.dumps(payload))


def test_fault_report_rejects_missing_and_unknown_keys(tree_report):
    payload, _ = tree_report

    bad = copy.deepcopy(payload)
    del bad["defect_rate"]
    with pytest.raises(ValueError, match=r"missing keys.*defect_rate"):
        robustness.validate_fault_report(bad)

    bad = copy.deepcopy(payload)
    bad["surprise"] = 1
    with pytest.raises(ValueError, match=r"unknown keys.*surprise"):
        robustness.validate_fault_report(bad)

    bad = copy.deepcopy(payload)
    del bad["points"][0]["single_fault"]["worst_drop"]
    with pytest.raises(ValueError,
                       match=r"single_fault.*missing keys.*worst_drop"):
        robustness.validate_fault_report(bad)

    bad = copy.deepcopy(payload)
    bad["points"][0]["monte_carlo"]["extra"] = 0
    with pytest.raises(ValueError, match=r"monte_carlo.*unknown keys"):
        robustness.validate_fault_report(bad)

    bad = copy.deepcopy(payload)
    bad["points"][0]["critical_gates"][0].pop("drop")
    with pytest.raises(ValueError,
                       match=r"critical_gates\[0\].*missing keys"):
        robustness.validate_fault_report(bad)

    bad = copy.deepcopy(payload)
    bad["points"][0]["n_faults"] += 1
    with pytest.raises(ValueError, match=r"not 2 \* n_sites"):
        robustness.validate_fault_report(bad)

    bad = copy.deepcopy(payload)
    bad["points"][0]["zero_fault_matches_simulate"] = False
    with pytest.raises(ValueError, match="diverged"):
        robustness.validate_fault_report(bad)


def test_select_points():
    class FakeArtifact:
        points = [{"acc_loss": 0.0, "norm_area": 0.9},
                  {"acc_loss": 0.005, "norm_area": 0.5},
                  {"acc_loss": 0.2, "norm_area": 0.1}]

        def best_under_loss(self, max_loss=0.01):
            ok = [i for i, p in enumerate(self.points)
                  if p["acc_loss"] <= max_loss]
            return min(ok, key=lambda i: self.points[i]["norm_area"]) \
                if ok else None

    art = FakeArtifact()
    assert robustness.select_points(art, "all") == [0, 1, 2]
    assert robustness.select_points(art, "best") == [1]
    assert robustness.select_points(art, "2") == [2]
    with pytest.raises(ValueError, match="out of range"):
        robustness.select_points(art, "7")
    art.points = [{"acc_loss": 0.5, "norm_area": 0.5}]
    with pytest.raises(ValueError, match="no pareto point"):
        robustness.select_points(art, "best")


# --- CLI: campaign end-to-end + hardening ----------------------------------

def test_faults_cli_end_to_end(tree_report, tmp_path, capsys):
    _, out = tree_report
    report_path = str(tmp_path / "fault_report.json")
    faults_main(["--pareto", out + "/pareto.json", "--point", "best",
                 "--trials", "4", "--out", report_path])
    report = robustness.load_fault_report(report_path)
    assert report["dataset"] == "seeds"
    assert len(report["points"]) == 1
    assert "report:" in capsys.readouterr().out


@pytest.mark.parametrize("cli", [faults_main, serve_main],
                         ids=["faults", "serve"])
def test_cli_exits_cleanly_on_missing_artifact(cli, tmp_path, capsys):
    """Missing pareto.json: exit code 2 + a one-line named error on stderr,
    never a traceback."""
    missing = str(tmp_path / "nope" / "pareto.json")
    with pytest.raises(SystemExit) as exc:
        cli(["--pareto", missing])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert f"error: pareto artifact {missing}" in err
    assert "FileNotFoundError" in err
    assert "Traceback" not in err


@pytest.mark.parametrize("cli", [faults_main, serve_main],
                         ids=["faults", "serve"])
def test_cli_exits_cleanly_on_truncated_artifact(cli, tmp_path, capsys):
    """Truncated JSON (simulated torn write): same clean exit contract."""
    path = str(tmp_path / "pareto.json")
    with open(path, "w") as f:
        f.write('{"backend": "reference", "pareto": [{"acc_l')
    with pytest.raises(SystemExit) as exc:
        cli(["--pareto", path])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert f"error: pareto artifact {path}" in err
    assert "JSONDecodeError" in err
    assert "Traceback" not in err


def test_cli_exits_cleanly_on_schema_violation(tmp_path, capsys):
    """Valid JSON, invalid schema: the named ValueError surfaces as the
    one-line error, not a stack dump."""
    path = str(tmp_path / "pareto.json")
    with open(path, "w") as f:
        json.dump({"backend": "reference"}, f)
    with pytest.raises(SystemExit) as exc:
        faults_main(["--pareto", path])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "ValueError" in err and "missing keys" in err
