"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, shape + no-NaN assertions, decode consistency."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config, shapes_for
from repro.data import SyntheticLMData
from repro.models import lm, transformer
from repro.runtime import lm_serve as serve, train
from repro.optim import get_optimizer


def _setup(arch, **over):
    cfg = reduced_config(get_config(arch), **over)
    if cfg.n_experts:  # disable capacity drops for determinism checks
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    tok = jax.random.randint(key, (b, s - cfg.prefix_len), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.prefix_len:
        batch["prefix_embed"] = jax.random.normal(
            key, (b, cfg.prefix_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg, params = _setup(arch)
    batch = _batch(cfg)
    hidden, caches, aux = transformer.forward(
        params, cfg, batch["tokens"], prefix_embed=batch.get("prefix_embed"))
    b = batch["tokens"].shape[0]
    s_total = batch["tokens"].shape[1] + cfg.prefix_len
    assert hidden.shape == (b, s_total, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(hidden)))
    loss, metrics = lm.lm_loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    # sane magnitude for a fresh model: ~ln(vocab)
    assert float(loss) < np.log(cfg.padded_vocab) + 2.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg, params = _setup(arch)
    step_fn = jax.jit(train.make_train_step(cfg))
    state = train.init_train_state(params, get_optimizer(cfg))
    batch = _batch(cfg)
    new_state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.step) == 1
    # params actually moved
    delta = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b_.astype(jnp.float32)))),
        state.params, new_state.params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg, params = _setup(arch)
    s = 24
    batch = _batch(cfg, s=s)
    tok = batch["tokens"]
    hidden, _, _ = transformer.forward(
        params, cfg, tok, prefix_embed=batch.get("prefix_embed"))
    full = transformer.logits_from_hidden(params, cfg, hidden[:, -1:, :])
    pre = {"tokens": tok[:, :-1]}
    if cfg.prefix_len:
        pre["prefix_embed"] = batch["prefix_embed"]
    _, caches = lm.prefill(params, cfg, pre)
    caches = lm.extend_caches(cfg, caches, s + 4)
    got, _ = lm.decode_step(params, cfg, tok[:, -1:], caches, jnp.int32(s - 1))
    rel = float(jnp.max(jnp.abs(full - got))) / (
        float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 2e-3, rel


def test_generate_runs_and_is_deterministic():
    cfg, params = _setup("llama3.2-3b")
    batch = _batch(cfg, s=16)
    out1 = serve.generate(params, cfg, batch, n_tokens=5, s_max=32)
    out2 = serve.generate(params, cfg, batch, n_tokens=5, s_max=32)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.vocab_size


def test_train_loss_decreases_on_fixed_batch():
    cfg, params = _setup("gemma-2b")
    step_fn = jax.jit(train.make_train_step(cfg))
    state = train.init_train_state(params, get_optimizer(cfg))
    data = SyntheticLMData(cfg.vocab_size, 64, 4, seed=1)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    first = None
    for _ in range(10):
        state, metrics = step_fn(state, batch)
        first = first if first is not None else float(metrics["loss"])
    assert float(metrics["loss"]) < first - 0.5, (first, float(metrics["loss"]))


def test_grad_accum_matches_single_batch():
    """grad_accum=2 must equal the full-batch gradient step (linear loss)."""
    cfg1, params = _setup("minitron-8b")
    cfg2 = dataclasses.replace(cfg1, grad_accum=2)
    batch = _batch(cfg1, b=4, s=32)
    s1 = train.init_train_state(params, get_optimizer(cfg1))
    s2 = train.init_train_state(params, get_optimizer(cfg2))
    n1, m1 = jax.jit(train.make_train_step(cfg1))(s1, batch)
    n2, m2 = jax.jit(train.make_train_step(cfg2))(s2, batch)
    # losses match closely; params match to optimizer-noise tolerance
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    diff = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b_.astype(jnp.float32)))),
        n1.params, n2.params)
    assert max(jax.tree.leaves(diff)) < 5e-3


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-7b"])
def test_subquadratic_archs_run_long_shape(arch):
    assert "long_500k" in shapes_for(get_config(arch))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a not in ("mamba2-1.3b", "zamba2-7b")])
def test_full_attention_archs_skip_long_shape(arch):
    assert "long_500k" not in shapes_for(get_config(arch))
