"""Loop-aware HLO analyzer: unit tests on synthetic HLO + an invariance
check on a real compiled module."""
import textwrap

from repro.launch import hlo_analysis as ha

SYNTH = textwrap.dedent("""
    HloModule test

    %body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,4]{1,0} constant({...})
      %d = f32[8,4]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ag = f32[8,16]{1,0} all-gather(%d), channel_id=1, replica_groups=[4]<=[4], dimensions={1}
      %r = (s32[], f32[8,16]) tuple(%x, %ag)
    }

    %cond.1 (p: (s32[], f32[8,16])) -> pred[] {
      %p2 = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(7)
      %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (a: f32[8,16]) -> f32[8,16] {
      %a = f32[8,16]{1,0} parameter(0)
      %t = (s32[], f32[8,16]) tuple(%a, %a)
      %w2 = (s32[], f32[8,16]) while(%t), condition=%cond.1, body=%body.1
      %out = f32[8,16]{1,0} get-tuple-element(%w2), index=1
    }
""")


def test_synthetic_while_multiplies_trip_count():
    tot = ha.analyze(SYNTH)
    # dot: 2 * (8*4) * 16 = 1024 flops, x 7 loop trips
    assert tot["flops"] == 1024 * 7
    ag = tot["collectives"]["all-gather"]
    assert ag["count"] == 7
    assert ag["bytes"] == 8 * 16 * 4 * 7


def test_parse_finds_computations_and_tripcount():
    comps, entries = ha.parse_computations(SYNTH)
    assert set(comps) >= {"body.1", "cond.1", "main"}
    assert comps["cond.1"].max_const == 7
    assert any(kind.startswith("while_body:") and n == "body.1"
               for n, kind in comps["main"].callees)


def test_real_module_scales_with_layers():
    """Compiled 1-layer vs 2-layer model: loop-aware flops must ~double for
    the scanned part (plain cost_analysis reports them equal)."""
    import jax
    import jax.numpy as jnp

    def make(nl):
        def f(ws, x):
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, ws)
            return h.sum()
        ws = jnp.zeros((nl, 64, 64))
        x = jnp.zeros((8, 64))
        return jax.jit(f).lower(ws, x).compile()

    t1 = ha.analyze(make(4).as_text())
    t2 = ha.analyze(make(8).as_text())
    assert t1["flops"] > 0
    ratio = t2["flops"] / t1["flops"]
    assert 1.7 < ratio < 2.3, ratio
