"""Fused fitness pipeline (DESIGN.md §12): the population-tiled Pallas
`fitness_errors` kernel vs the reference backend and the materializing
`tree_infer_scores` oracle — bit-exact on trees AND forests, including
ragged tile edges and the sweep's inert-padded genes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.datasets import load_dataset
from repro.core import forest as forest_mod, quant
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.kernels import ops, ref
from repro import search
from repro.search import sweep as sweep_mod


@pytest.fixture(scope="module")
def tree_problem():
    ds = load_dataset("vertebral")
    pt = to_parallel(train_tree(ds.x_train, ds.y_train, ds.n_classes))
    return search.build_tree_problem(pt, ds.x_test, ds.y_test)


@pytest.fixture(scope="module")
def forest_problem():
    ds = load_dataset("seeds")
    fr = forest_mod.train_forest(ds.x_train, ds.y_train, ds.n_classes,
                                 n_trees=4)
    return search.build_forest_problem(fr, ds.x_test, ds.y_test)


def _fit_operands(problem):
    return ops.prepare_fitness_operands(
        problem.x_sel, problem.y, problem.path, problem.path_len,
        problem.n_neg, problem.leaf_class, problem.n_classes)


# ---------------------------------------------------------------------------
# objectives: fused kernel backend == reference backend, array-for-array
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 2**31 - 1), pop=st.integers(1, 21),
       block_p=st.sampled_from([1, 3, 8, 16]))
def test_fused_objectives_bitexact_tree(tree_problem, seed, pop, block_p):
    """Tree problem, ragged population edges (P not a block_p multiple)."""
    f_ref = search.make_fitness(tree_problem, "reference")
    f_ker = search.make_fitness(tree_problem, "kernel", interpret=True,
                                block_p=block_p)
    genes = jax.random.uniform(jax.random.PRNGKey(seed),
                               (pop, tree_problem.n_genes))
    np.testing.assert_array_equal(np.asarray(f_ref(genes)),
                                  np.asarray(f_ker(genes)))


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 2**31 - 1), block_p=st.sampled_from([2, 8]),
       block_b=st.sampled_from([128, 256]),
       block_l=st.sampled_from([None, 128]))
def test_fused_objectives_bitexact_forest(forest_problem, seed, block_p,
                                          block_b, block_l):
    """Forest problem: block-diagonal super-tree with leaf-axis tiling and
    a batch size that is not a block_b multiple."""
    f_ref = search.make_fitness(forest_problem, "reference")
    f_ker = search.make_fitness(forest_problem, "kernel", interpret=True,
                                block_p=block_p, block_b=block_b,
                                block_l=block_l)
    genes = jax.random.uniform(jax.random.PRNGKey(seed),
                               (11, forest_problem.n_genes))
    np.testing.assert_array_equal(np.asarray(f_ref(genes)),
                                  np.asarray(f_ker(genes)))


def test_fused_exact_genes_zero_loss(tree_problem):
    """The exact 8-bit zero-margin chromosome scores (to f32 rounding of the
    stored reference point) zero loss and unit area through the fused path,
    bit-identical to the reference backend."""
    g = jnp.asarray(tree_problem.exact_genes())[None]
    f_ref = search.make_fitness(tree_problem, "reference")
    f_ker = search.make_fitness(tree_problem, "kernel", interpret=True)
    objs = np.asarray(f_ker(g))
    np.testing.assert_array_equal(objs, np.asarray(f_ref(g)))
    assert abs(objs[0, 0]) < 1e-6
    assert np.isclose(objs[0, 1], 1.0)


# ---------------------------------------------------------------------------
# error counts: fused kernel == argmax(tree_infer_scores) == jnp oracle
# ---------------------------------------------------------------------------

def test_fitness_errors_matches_tree_infer_scores_oracle(forest_problem):
    """The materializing kernel stays the bit-exact oracle of the fused one:
    errors == count(argmax(tree_infer_scores) != y), chromosome by
    chromosome."""
    prob = forest_problem
    fit_ops = _fit_operands(prob)
    ti_ops = ops.prepare_operands(
        prob.feature, prob.path, prob.path_len, prob.n_neg, prob.leaf_class,
        prob.n_classes, prob.n_features)
    genes = jax.random.uniform(jax.random.PRNGKey(7), (9, prob.n_genes))
    scale, thr, vote_cap = ops.decode_population(prob.threshold, genes)
    errors = np.asarray(ops.fitness_errors(fit_ops, scale, thr, vote_cap,
                                           interpret=True))
    preds = np.asarray(ops.tree_infer_predict(prob.x8, ti_ops, scale, thr,
                                              vote_cap, interpret=True))
    want = (preds != np.asarray(prob.y)[None, :]).sum(axis=1)
    np.testing.assert_array_equal(errors, want.astype(np.float32))


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 2**31 - 1), block_p=st.sampled_from([1, 2, 8]),
       block_b=st.sampled_from([128, 256]))
def test_raw_kernel_matches_ref_oracle_padded_ops(tree_problem, seed,
                                                  block_p, block_b):
    """Raw kernel vs kernels.ref on identical padded operands: the
    lane-replicated accumulator holds the same correct count in every lane."""
    from repro.kernels.fitness import fitness_errors as raw_kernel
    prob = tree_problem
    x_sel, path_t, target, cls1h, y_row = _fit_operands(prob)
    rng = np.random.default_rng(seed)
    n = x_sel.shape[1]
    p = 8
    bits = rng.integers(2, 9, (p, n))
    scale = jnp.asarray(np.exp2(-(8 - bits)).astype(np.float32))
    thr = jnp.asarray(rng.integers(0, 256, (p, n)).astype(np.float32))
    # mixed exact/approx vote caps (lane-replicated for the kernel operand)
    cap = jnp.asarray(np.where(rng.integers(0, 2, p) > 0, 1.0,
                               np.inf).astype(np.float32))
    from repro.kernels.fitness import LANES
    vcap = jnp.broadcast_to(cap[:, None], (p, LANES))
    x_pad = ops._pad_to(x_sel, block_b, 0)
    y_pad = ops._pad_to(y_row, block_b, 1, value=-1.0)
    got = np.asarray(raw_kernel(x_pad, scale, thr, path_t, target, cls1h,
                                y_pad, vcap, block_p=block_p, block_b=block_b,
                                interpret=True))
    want = np.asarray(ref.fitness_correct_counts(
        x_pad, scale, thr, path_t, target, cls1h, y_pad, cap))
    for lane in (0, 1, 127):
        np.testing.assert_array_equal(got[:, lane], want)


# ---------------------------------------------------------------------------
# the sweep's inert-padded genes ride the fused path unchanged
# ---------------------------------------------------------------------------

def test_fused_errors_on_sweep_padded_problem(tree_problem, forest_problem):
    """Run the fused kernel on a sweep-padded problem (§11 inert padding):
    pad-gene columns never change the error counts, and the counts match
    the real problem's reference predictions."""
    problems = {"tree": tree_problem, "forest": forest_problem}
    (bucket,) = sweep_mod.plan_buckets(problems, max_buckets=1)
    rng = np.random.default_rng(3)
    for name, problem in problems.items():
        pp = sweep_mod.pad_problem(problem, bucket.dims)
        leaf_class = np.asarray(jnp.argmax(pp.leaf_onehot, axis=1))
        fit_ops = ops.prepare_fitness_operands(
            pp.x_sel, pp.y, pp.path, pp.path_len, pp.n_neg,
            leaf_class, int(pp.leaf_onehot.shape[1]))

        g_real = rng.uniform(0, 1, problem.n_genes).astype(np.float32)
        a = rng.uniform(0, 1, (1, pp.n_genes)).astype(np.float32)
        b = rng.uniform(0, 1, (1, pp.n_genes)).astype(np.float32)
        # §16 layout: real comparator genes are a prefix, but the trailing
        # vote gene lives in the LAST padded column (TreeFamily.unpad_genes)
        n_comp_genes = problem.n_genes - 1
        for g in (a, b):
            g[0, :n_comp_genes] = g_real[:n_comp_genes]
            g[0, -1] = g_real[-1]

        errs = []
        for g in (a, b):
            scale, thr, vote_cap = ops.decode_population(pp.threshold,
                                                         jnp.asarray(g))
            errs.append(np.asarray(ops.fitness_errors(
                fit_ops, scale, thr, vote_cap, interpret=True)))
        np.testing.assert_array_equal(errs[0], errs[1], err_msg=name)

        bits, t_sub, vote_cap = search.decode_chromosome(problem,
                                                         jnp.asarray(g_real))
        pred = np.asarray(search.predict_votes(problem, bits, t_sub,
                                               vote_cap))
        want = float((pred != np.asarray(problem.y)).sum())
        assert errs[0][0] == want, name


# ---------------------------------------------------------------------------
# hoisted prep + shared decode plumbing
# ---------------------------------------------------------------------------

def test_problem_x_sel_is_hoisted_gather(tree_problem, forest_problem):
    for prob in (tree_problem, forest_problem):
        want = np.asarray(prob.x8)[:, np.asarray(prob.feature)]
        np.testing.assert_array_equal(np.asarray(prob.x_sel), want)


def test_decode_population_full_consistent(tree_problem):
    """The shared decode returns exactly what the two historical decodes
    produced — (scale, thr) for the kernel, (bits, t_sub) for the area LUT —
    with DESIGN.md §16 truncation folded into the EFFECTIVE operands."""
    genes = jax.random.uniform(jax.random.PRNGKey(11),
                               (6, tree_problem.n_genes))
    scale, t_sub, bits, vote_cap = ops.decode_population_full(
        tree_problem.threshold, genes)
    scale2, thr2, vote_cap2 = ops.decode_population(tree_problem.threshold,
                                                    genes)
    np.testing.assert_array_equal(np.asarray(scale), np.asarray(scale2))
    np.testing.assert_array_equal(np.asarray(t_sub, np.float32),
                                  np.asarray(thr2))
    np.testing.assert_array_equal(np.asarray(vote_cap), np.asarray(vote_cap2))
    bits_w, margin, trunc_w, vote_w = quant.decode_tree_genes(genes)
    t_sub_w = quant.substitute(
        quant.threshold_to_int(tree_problem.threshold[None, :], bits_w),
        margin, bits_w)
    np.testing.assert_array_equal(np.asarray(bits),
                                  np.asarray(bits_w - trunc_w))
    np.testing.assert_array_equal(
        np.asarray(t_sub), np.asarray(jnp.right_shift(t_sub_w, trunc_w)))
    np.testing.assert_array_equal(
        np.asarray(vote_cap),
        np.where(np.asarray(vote_w) > 0, np.float32(1.0), np.float32(np.inf)))


def test_fitness_errors_rejects_bad_blocking(tree_problem):
    from repro.kernels.fitness import fitness_errors as raw_kernel
    x_sel, path_t, target, cls1h, y_row = _fit_operands(tree_problem)
    x_pad = ops._pad_to(x_sel, 256, 0)
    y_pad = ops._pad_to(y_row, 256, 1, value=-1.0)
    n = x_sel.shape[1]
    scale = jnp.ones((6, n), jnp.float32)
    from repro.kernels.fitness import LANES
    vcap = jnp.full((6, LANES), jnp.inf, jnp.float32)
    with pytest.raises(ValueError, match="block_p"):
        raw_kernel(x_pad, scale, scale, path_t, target, cls1h, y_pad, vcap,
                   block_p=4, block_b=256, interpret=True)
