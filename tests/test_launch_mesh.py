"""`make_search_mesh` spec parsing: the one constructor behind every
`--mesh` knob (DESIGN.md §13). Runs on however many devices the host has —
single-device environments exercise the error paths."""
import jax
import pytest

from repro.launch.mesh import make_search_mesh


def test_none_specs_mean_single_device_path():
    assert make_search_mesh(None) is None
    assert make_search_mesh("") is None
    assert make_search_mesh("none") is None


def test_auto_uses_all_devices_on_last_axis():
    n = len(jax.devices())
    mesh = make_search_mesh("auto", axes=("pop",))
    assert mesh.shape == {"pop": n}
    mesh2 = make_search_mesh("auto", axes=("bucket", "pop"))
    assert mesh2.shape == {"bucket": 1, "pop": n}


def test_single_extent_lands_on_last_axis():
    mesh = make_search_mesh("1", axes=("bucket", "pop"))
    assert mesh.shape == {"bucket": 1, "pop": 1}


def test_explicit_extents_match_axes():
    mesh = make_search_mesh("1x1", axes=("bucket", "pop"))
    assert tuple(mesh.axis_names) == ("bucket", "pop")


def test_rejects_garbage_and_bad_extents():
    with pytest.raises(ValueError, match="bad mesh spec"):
        make_search_mesh("junk")
    with pytest.raises(ValueError, match=">= 1"):
        make_search_mesh("0")
    with pytest.raises(ValueError, match="axes"):
        make_search_mesh("1x1x1", axes=("bucket", "pop"))


def test_rejects_more_devices_than_host_has():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        make_search_mesh(str(n + 1))
