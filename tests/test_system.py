"""End-to-end behaviour of the paper's system: train -> approximate -> pareto.

This is the paper's headline claim in miniature: NSGA-II over the dual
comparator approximation yields designs with large area reduction at small
(or negative) accuracy loss, all dominating or matching the exact bespoke
design (paper Fig. 5, Tables I/II).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.datasets import load_dataset
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.core import approx, area, nsga2, quant, rtl


@pytest.fixture(scope="module")
def searched():
    ds = load_dataset("vertebral")
    tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
    pt = to_parallel(tree)
    prob = approx.build_problem(pt, ds.x_test, ds.y_test)
    fit = approx.make_fitness_fn(prob)
    cfg = nsga2.NSGA2Config(pop_size=48, n_generations=30)
    state = nsga2.run(jax.random.PRNGKey(0), fit, prob.n_genes, cfg)
    return ds, tree, pt, prob, state


def test_exact_design_objectives(searched):
    _, _, pt, prob, _ = searched
    fit = approx.make_fitness_fn(prob)
    o = np.asarray(
        fit(jnp.asarray(quant.exact_tree_genes(pt.n_comparators))[None]))[0]
    assert abs(o[0]) < 1e-6      # zero accuracy loss vs itself
    assert abs(o[1] - 1.0) < 1e-6  # unit normalized area


def test_pareto_dominates_exact(searched):
    """Paper: every derived solution has lower area than the exact design."""
    _, _, _, _, state = searched
    objs, _ = nsga2.pareto_front(state.objs, state.genes)
    assert (objs[:, 1] < 1.0).all()


def test_area_reduction_at_paper_thresholds(searched):
    """Paper Table II: >= 1.5x area reduction at the 1% loss threshold."""
    _, _, _, _, state = searched
    objs, _ = nsga2.pareto_front(state.objs, state.genes)
    ok1 = objs[objs[:, 0] <= 0.01 + 1e-6]
    assert len(ok1) > 0
    best_area = ok1[:, 1].min()
    assert best_area < 1 / 1.5, f"area reduction only {1/best_area:.2f}x"


def test_power_tracks_area(searched):
    _, _, pt, prob, state = searched
    objs, _ = nsga2.pareto_front(state.objs, state.genes)
    a_mm2 = objs[:, 1] * prob.exact_area_mm2
    p_mw = np.array([area.power_mw(a) for a in a_mm2])
    np.testing.assert_allclose(p_mw / a_mm2, area.POWER_PER_MM2_MW,
                               rtol=1e-6)


def test_rtl_emission(searched):
    _, _, pt, prob, state = searched
    objs, genes = nsga2.pareto_front(state.objs, state.genes)
    bits, marg, trunc, _ = quant.decode_tree_genes(jnp.asarray(genes[0]))
    t_int = quant.substitute(
        quant.threshold_to_int(jnp.asarray(pt.threshold), bits), marg, bits)
    # emit the EFFECTIVE design: §16 truncation folds into width/threshold
    bits = bits - trunc
    t_int = jnp.right_shift(t_int, trunc)
    v = rtl.emit_verilog(pt, np.asarray(bits), np.asarray(t_int))
    assert v.count("wire d") == pt.n_comparators
    assert v.count("wire leaf") == pt.n_leaves
    assert "module bespoke_dtree" in v and "endmodule" in v
    # exact design at full precision contains 8-bit slices
    eb = np.full(pt.n_comparators, 8)
    t8 = np.clip(np.floor(pt.threshold * 256).astype(int), 0, 255)
    v8 = rtl.emit_verilog(pt, eb, t8)
    assert "[7:0] >" in v8
