"""NSGA-II: domination/sort/crowding correctness + end-to-end convergence."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import nsga2


def brute_force_ranks(objs: np.ndarray) -> np.ndarray:
    n = objs.shape[0]
    dom = np.zeros((n, n), dtype=bool)
    for i in range(n):
        for j in range(n):
            dom[i, j] = np.all(objs[i] <= objs[j]) and np.any(objs[i] < objs[j])
    rank = np.full(n, -1)
    r = 0
    remaining = set(range(n))
    while remaining:
        front = [j for j in remaining if not any(dom[i, j] for i in remaining)]
        for j in front:
            rank[j] = r
        remaining -= set(front)
        r += 1
    return rank


@settings(deadline=None, max_examples=25)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(2, 40),
    st.integers(1, 3),
)
def test_nd_sort_matches_bruteforce(seed, n, m):
    rng = np.random.default_rng(seed)
    # duplicates included on purpose
    objs = rng.integers(0, 4, size=(n, m)).astype(np.float32)
    got = np.asarray(nsga2.non_dominated_sort(jnp.asarray(objs)))
    want = brute_force_ranks(objs)
    np.testing.assert_array_equal(got, want)


def test_domination_matrix_basics():
    objs = jnp.array([[0.0, 0.0], [1.0, 1.0], [0.0, 1.0], [0.0, 0.0]])
    d = np.asarray(nsga2.domination_matrix(objs))
    assert d[0, 1] and d[0, 2] and not d[1, 0]
    assert not d[0, 3] and not d[3, 0]  # equal points don't dominate
    assert not d.diagonal().any()


def test_crowding_extremes_are_infinite():
    objs = jnp.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    rank = jnp.zeros(4, jnp.int32)
    c = np.asarray(nsga2.crowding_distance(objs, rank))
    assert c[0] > 1e8 and c[3] > 1e8
    assert c[1] < 1e8 and c[2] < 1e8
    assert np.isclose(c[1], c[2])


def _crowding_distance_loop(objs, rank):
    """The historical Python-loop formulation — the bit-exactness oracle the
    vmapped `crowding_distance` is pinned against."""
    p, m = objs.shape
    dist = jnp.zeros((p,), dtype=jnp.float32)
    for k in range(m):
        v = objs[:, k]
        key = rank.astype(jnp.float32) * nsga2._BIG + v
        order = jnp.argsort(key)
        v_s = v[order]
        r_s = rank[order]
        prev_ok = jnp.concatenate([jnp.array([False]), r_s[1:] == r_s[:-1]])
        next_ok = jnp.concatenate([r_s[:-1] == r_s[1:], jnp.array([False])])
        v_prev = jnp.concatenate([v_s[:1], v_s[:-1]])
        v_next = jnp.concatenate([v_s[1:], v_s[-1:]])
        fmin = jnp.full((p,), jnp.inf).at[r_s].min(v_s)
        fmax = jnp.full((p,), -jnp.inf).at[r_s].max(v_s)
        span = jnp.maximum((fmax - fmin)[r_s], 1e-12)
        d = jnp.where(prev_ok & next_ok, (v_next - v_prev) / span, jnp.inf)
        dist = dist.at[order].add(jnp.where(jnp.isinf(d), nsga2._BIG, d))
    return dist


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 60),
       m=st.integers(1, 4))
def test_crowding_vmap_bitexact_vs_loop(seed, n, m):
    """The vmapped-over-objectives crowding distance is bit-identical to the
    sequential per-objective loop, duplicates and multi-front ranks
    included."""
    rng = np.random.default_rng(seed)
    objs = jnp.asarray(rng.integers(0, 4, size=(n, m)).astype(np.float32))
    rank = nsga2.non_dominated_sort(objs)
    got = np.asarray(nsga2.crowding_distance(objs, rank))
    want = np.asarray(_crowding_distance_loop(objs, rank))
    np.testing.assert_array_equal(got, want)


def test_operators_stay_in_bounds():
    key = jax.random.PRNGKey(0)
    a = jax.random.uniform(key, (32, 10))
    b = jax.random.uniform(jax.random.PRNGKey(1), (32, 10))
    o1, o2 = nsga2._sbx(key, a, b, 20.0, 0.9)
    assert float(o1.min()) >= 0 and float(o1.max()) <= 1
    m = nsga2._poly_mutation(key, a, 20.0, 0.5)
    assert float(m.min()) >= 0 and float(m.max()) <= 1


def test_nsga2_converges_on_zdt1_like():
    """Front should approach the analytic pareto set of a ZDT1-style problem."""
    def fitness(pop):
        f1 = pop[:, 0]
        g = 1.0 + 9.0 * pop[:, 1:].mean(axis=1)
        f2 = g * (1.0 - jnp.sqrt(f1 / g))
        return jnp.stack([f1, f2], axis=1)

    cfg = nsga2.NSGA2Config(pop_size=48, n_generations=60)
    state = nsga2.run(jax.random.PRNGKey(0), jax.jit(fitness), 6, cfg)
    objs, _ = nsga2.pareto_front(state.objs, state.genes)
    # analytic front: f2 = 1 - sqrt(f1); mean gap should be small
    gap = np.mean(np.abs(objs[:, 1] - (1.0 - np.sqrt(objs[:, 0]))))
    assert gap < 0.25, gap
    assert len(objs) > 5


def test_elitism_never_regresses_best_objective():
    def fitness(pop):
        return jnp.stack([pop[:, 0], 1.0 - pop[:, 0]], axis=1)

    cfg = nsga2.NSGA2Config(pop_size=16, n_generations=1)
    key = jax.random.PRNGKey(2)
    state = nsga2.init_state(key, jax.jit(fitness), 4, cfg)
    step = jax.jit(nsga2.make_step(jax.jit(fitness), cfg))
    best = float(state.objs[:, 0].min())
    for _ in range(10):
        state = step(state)
        new_best = float(state.objs[:, 0].min())
        assert new_best <= best + 1e-7
        best = new_best


def _zdt1(pop):
    f1 = pop[:, 0]
    g = 1.0 + 9.0 * pop[:, 1:].mean(axis=1)
    f2 = g * (1.0 - jnp.sqrt(f1 / g))
    return jnp.stack([f1, f2], axis=1)


def test_make_chunk_bitexact_vs_stepped_loop():
    """lax.scan over make_step == calling the jitted step N times (§9)."""
    cfg = nsga2.NSGA2Config(pop_size=24, n_generations=9)
    fitness = jax.jit(_zdt1)
    state = nsga2.init_state(jax.random.PRNGKey(4), fitness, 6, cfg)

    stepped = state
    step = jax.jit(nsga2.make_step(fitness, cfg))
    for _ in range(9):
        stepped = step(stepped)

    chunked = jax.jit(nsga2.make_chunk(fitness, cfg, 9))(state)
    # and an uneven chunk split (4 + 5) through the same scan machinery
    split = jax.jit(nsga2.make_chunk(fitness, cfg, 5))(
        jax.jit(nsga2.make_chunk(fitness, cfg, 4))(state))
    for got in (chunked, split):
        np.testing.assert_array_equal(np.asarray(stepped.genes),
                                      np.asarray(got.genes))
        np.testing.assert_array_equal(np.asarray(stepped.objs),
                                      np.asarray(got.objs))
        np.testing.assert_array_equal(np.asarray(stepped.key),
                                      np.asarray(got.key))
        assert int(got.generation) == 9


def test_make_chunk_rejects_empty_chunk():
    import pytest
    with pytest.raises(ValueError):
        nsga2.make_chunk(jax.jit(_zdt1), nsga2.NSGA2Config(), 0)


def test_domination_kernel_routing_matches_jnp_path(monkeypatch):
    """Above DOMINATION_KERNEL_MIN_POP (on TPU; forced here, so the kernel
    runs interpreted) the sort routes through the Pallas kernel and must
    equal the jnp oracle."""
    rng = np.random.default_rng(9)
    objs = jnp.asarray(rng.uniform(0, 1, (48, 2)).astype(np.float32))
    want = np.asarray(nsga2.non_dominated_sort(objs,
                                               nsga2.domination_matrix(objs)))
    monkeypatch.setattr(nsga2, "DOMINATION_KERNEL_MIN_POP", 16)
    monkeypatch.setattr(nsga2, "_kernel_domination_available", lambda: True)
    got = np.asarray(nsga2.non_dominated_sort(objs))
    np.testing.assert_array_equal(got, want)


def test_domination_routing_stays_jnp_off_tpu(monkeypatch):
    """Off-TPU, big pools must NOT be auto-routed into the interpreter."""
    monkeypatch.setattr(nsga2, "DOMINATION_KERNEL_MIN_POP", 16)
    monkeypatch.setattr(nsga2, "_kernel_domination_available", lambda: False)
    calls = []
    real = nsga2.domination_matrix
    monkeypatch.setattr(nsga2, "domination_matrix",
                        lambda objs, against=None:
                        calls.append(1) or real(objs, against))
    objs = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (48, 2)),
                       dtype=jnp.float32)
    nsga2.non_dominated_sort(objs)
    assert calls  # the pure-jnp path ran


def test_domination_routing_decides_on_local_rows(monkeypatch):
    """Kernel routing keys on objs.shape[0] — the LOCAL (post-shard) row
    count — not the global column count: a small per-shard slab of a large
    gathered pool must stay on the jnp path, and a slab at the threshold
    must engage the kernel (DESIGN.md §13)."""
    from repro.kernels import ops as kops

    monkeypatch.setattr(nsga2, "DOMINATION_KERNEL_MIN_POP", 64)
    monkeypatch.setattr(nsga2, "_kernel_domination_available", lambda: True)
    calls = []
    real = kops.domination_block_bool
    monkeypatch.setattr(kops, "domination_block_bool",
                        lambda a, b, **kw:
                        calls.append((a.shape[0], b.shape[0]))
                        or real(a, b, interpret=True))
    rng = np.random.default_rng(4)
    pool = jnp.asarray(rng.uniform(0, 1, (128, 2)), dtype=jnp.float32)
    rows_small = pool[:32]
    rows_big = pool[:64]
    want_small = np.asarray(nsga2.domination_matrix(rows_small, pool))
    want_big = np.asarray(nsga2.domination_matrix(rows_big, pool))
    got_small = np.asarray(nsga2._dispatch_domination(rows_small, pool))
    assert calls == []  # 32 rows < min pop: jnp, even though pool is 128
    got_big = np.asarray(nsga2._dispatch_domination(rows_big, pool))
    assert calls == [(64, 128)]  # 64 rows: the kernel engages
    np.testing.assert_array_equal(got_small, want_small)
    np.testing.assert_array_equal(got_big, want_big)
