"""Runtime substrate: checkpointing, data pipeline, compression, serving
(1-device)."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import SyntheticLMData
from repro.optim import compress
from repro.runtime import checkpoint


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)},
            "scalar": jnp.float32(3.5)}
    path = checkpoint.save(str(tmp_path), 7, tree)
    assert os.path.isdir(path)
    like = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
    restored, step = checkpoint.restore(str(tmp_path), 7, like)
    assert step == 7
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), tree, restored)


def test_checkpoint_retention_and_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in range(6):
        checkpoint.save(str(tmp_path), s, tree, keep=3)
    assert checkpoint.latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert len([d for d in kept if d.startswith("ckpt_")]) == 3


def test_checkpoint_crash_safety(tmp_path):
    """A leftover .tmp dir (simulated crash) never corrupts restore."""
    tree = {"x": jnp.arange(4.0)}
    checkpoint.save(str(tmp_path), 1, tree)
    os.makedirs(os.path.join(tmp_path, "ckpt_00000002.tmp"))
    assert checkpoint.latest_step(str(tmp_path)) == 1
    restored, _ = checkpoint.restore(str(tmp_path), 1, tree)
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.asarray(tree["x"]))


def test_checkpoint_resume_skips_truncated_npz(tmp_path):
    """Regression (DESIGN.md §17 satellite): a partially-written
    `arrays.npz` in the newest checkpoint must not kill the resume —
    `latest_step` warns, skips it, and falls back to the newest intact
    step, and `restore` of that step round-trips."""
    tree = {"x": jnp.arange(4.0), "n": {"y": jnp.ones((3,), jnp.int32)}}
    checkpoint.save(str(tmp_path), 1, tree)
    checkpoint.save(str(tmp_path), 2, tree)
    npz = os.path.join(tmp_path, "ckpt_00000002", "arrays.npz")
    with open(npz, "rb") as f:
        blob = f.read()
    with open(npz, "wb") as f:
        f.write(blob[: len(blob) // 2])   # torn write
    with pytest.warns(UserWarning, match="skipping unreadable checkpoint"):
        step = checkpoint.latest_step(str(tmp_path))
    assert step == 1
    restored, got = checkpoint.restore(str(tmp_path), step, tree)
    assert got == 1
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_checkpoint_resume_skips_corrupt_manifest(tmp_path):
    """Same fallback for a corrupt/incomplete manifest.json; with NO intact
    checkpoint left, latest_step reports None (fresh start) instead of
    crashing."""
    tree = {"x": jnp.arange(4.0)}
    checkpoint.save(str(tmp_path), 1, tree)
    checkpoint.save(str(tmp_path), 2, tree)
    with open(os.path.join(tmp_path, "ckpt_00000002",
                           "manifest.json"), "w") as f:
        f.write('{"step": 2, "keys"')   # truncated JSON
    with pytest.warns(UserWarning, match="ckpt_00000002"):
        assert checkpoint.latest_step(str(tmp_path)) == 1
    os.remove(os.path.join(tmp_path, "ckpt_00000001", "manifest.json"))
    with pytest.warns(UserWarning):
        assert checkpoint.latest_step(str(tmp_path)) is None


def test_data_pipeline_determinism_and_sharding():
    data = SyntheticLMData(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    b1 = data.batch(5)
    b2 = data.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 64)
    assert b1["tokens"].max() < 1000
    # shards partition the global batch deterministically
    shards = [data.batch(5, shard=i, n_shards=4)["tokens"] for i in range(4)]
    assert all(s.shape == (2, 64) for s in shards)
    # different steps differ
    assert not np.array_equal(b1["tokens"], data.batch(6)["tokens"])


def test_data_has_learnable_structure():
    """Bigram continuation rate is far above uniform chance."""
    data = SyntheticLMData(vocab_size=500, seq_len=256, global_batch=4, seed=0)
    toks = data.batch(0)["tokens"]
    succ = data._succ
    hits = 0
    total = 0
    for b in range(toks.shape[0]):
        for t in range(1, toks.shape[1]):
            hits += toks[b, t] in succ[toks[b, t - 1]]
            total += 1
    assert hits / total > 0.5


@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs import get_config, reduced_config
    from repro.models import transformer
    cfg = reduced_config(get_config("llama3.2-3b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    return cfg, params, {"tokens": tok}


def test_generate_zero_tokens_returns_empty(serve_setup):
    """Regression: n_tokens=0 used to return 1 token (the prefill argmax)."""
    from repro.runtime import lm_serve as serve
    cfg, params, batch = serve_setup
    out = serve.generate(params, cfg, batch, n_tokens=0, s_max=32)
    assert out.shape == (2, 0)


def test_generate_sampling_is_wired(serve_setup):
    """Regression: greedy/key used to be accepted but silently ignored —
    sampling degraded to argmax. Now: greedy ignores the key, sampling is
    key-deterministic, key-sensitive, and collapses to greedy as T -> 0."""
    from repro.runtime import lm_serve as serve
    cfg, params, batch = serve_setup
    greedy = serve.generate(params, cfg, batch, n_tokens=5, s_max=32)
    greedy_keyed = serve.generate(params, cfg, batch, n_tokens=5, s_max=32,
                                  key=jax.random.PRNGKey(7))
    assert greedy.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(greedy_keyed))

    sample = lambda k, t: serve.generate(
        params, cfg, batch, n_tokens=5, s_max=32, greedy=False,
        key=jax.random.PRNGKey(k), temperature=t)
    s1, s2 = sample(3, 2.0), sample(3, 2.0)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert 0 <= int(s1.min()) and int(s1.max()) < cfg.vocab_size
    # different keys must be able to produce different sequences
    assert any(not np.array_equal(np.asarray(s1), np.asarray(sample(k, 2.0)))
               for k in (5, 11, 23))
    # near-zero temperature collapses to the greedy sequence
    cold = sample(9, 1e-5)
    np.testing.assert_array_equal(np.asarray(cold), np.asarray(greedy))


def test_generate_sampling_requires_key(serve_setup):
    from repro.runtime import lm_serve as serve
    cfg, params, batch = serve_setup
    with pytest.raises(ValueError, match="key"):
        serve.generate(params, cfg, batch, n_tokens=2, s_max=32, greedy=False)


def test_int8_quantize_roundtrip_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 0.1, (256, 128)).astype(np.float32))
    q, s = compress.quantize_int8(g)
    back = compress.dequantize_int8(q, s)
    # error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-9
    assert q.dtype == jnp.int8
