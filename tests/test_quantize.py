"""LM mixed-precision bespoke quantization (the paper's technique carried to
the model zoo) + qmatmul integration."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.quantize import bespoke
from repro.kernels import ops as kops


@given(bits=st.integers(2, 8), margin=st.integers(0, 5))
def test_snap_lut_properties(bits, margin):
    lut = bespoke.snap_lut(bits, margin)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    codes = np.arange(lo, hi + 1)
    snapped = lut[codes - lo]
    # in range, never more expensive (popcount), and a fixpoint: the chase
    # means one lookup fully settles, but a chained hop (16 -> 18 -> 19 at
    # margin 2) may land further than `margin` from the ORIGINAL code, so
    # only per-hop distance — not total displacement — is bounded.
    assert snapped.min() >= lo and snapped.max() <= hi
    pc = lambda v: np.array([bin(abs(int(c))).count("1") for c in v])
    assert (pc(snapped) <= pc(codes)).all()
    np.testing.assert_array_equal(lut[snapped - lo], snapped)  # idempotent
    if margin == 0:
        np.testing.assert_array_equal(snapped, codes)


@settings(deadline=None, max_examples=10)
@given(bits=st.integers(2, 8), seed=st.integers(0, 2**31 - 1))
def test_quantize_tensor_error_bounded(bits, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.05, (32, 16)).astype(np.float32)
    codes, scale = bespoke.quantize_tensor(w, bits, margin=0)
    back = bespoke.dequantize_tensor(codes, scale)
    # max error ~ half a step per channel
    step = scale[0]
    assert (np.abs(back - w) <= step * 0.5 + 1e-7).all()


def test_quantized_matmul_through_kernel():
    """codes+scales from quantize_tensor run through kernels.qmatmul and
    match the dequantized-dense product."""
    rng = np.random.default_rng(0)
    w = rng.normal(0, 0.05, (256, 128)).astype(np.float32)
    x = rng.normal(size=(64, 256)).astype(np.float32)
    codes, scale = bespoke.quantize_tensor(w, bits=8, margin=0)
    got = kops.qmatmul(jnp.asarray(x), jnp.asarray(codes),
                       jnp.asarray(scale[0]), interpret=True)
    want = x @ bespoke.dequantize_tensor(codes, scale)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_apply_chromosome_cost_monotone_in_bits():
    from repro.configs import get_config, reduced_config
    from repro.models import transformer
    cfg = reduced_config(get_config("gemma-2b"), prefix_len=0)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    n = len(bespoke.quantizable_tensors(params))
    hi_bits = np.zeros(2 * n); hi_bits[0::2] = 0.99; hi_bits[1::2] = 0.0
    lo_bits = np.zeros(2 * n); lo_bits[0::2] = 0.0; lo_bits[1::2] = 0.0
    _, cost_hi = bespoke.apply_chromosome(params, hi_bits)
    _, cost_lo = bespoke.apply_chromosome(params, lo_bits)
    assert cost_lo < cost_hi
    # 8-bit cost must be below the bf16 baseline (=1.0)
    assert cost_hi < 1.0


def test_quant_search_smoke():
    """Tiny end-to-end search: pareto must trade loss against cost."""
    from repro.configs import get_config, reduced_config
    from repro.core import nsga2
    from repro.models import lm, transformer
    cfg = reduced_config(get_config("llama3.2-3b"), n_layers=1, d_model=32,
                         d_ff=64, vocab_size=128, loss_chunk=256)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab_size)}
    loss_fn = jax.jit(lambda p, b: lm.lm_loss(p, cfg, b)[0])
    fitness, n_genes, base = bespoke.make_lm_quant_problem(
        params, cfg, batch, loss_fn)
    ga = nsga2.NSGA2Config(pop_size=8, n_generations=3)
    state = nsga2.run(jax.random.PRNGKey(2),
                      lambda g: jnp.asarray(fitness(np.asarray(g))),
                      n_genes, ga, jit=False)
    objs, _ = nsga2.pareto_front(state.objs, state.genes)
    assert len(objs) >= 1
    assert (objs[:, 1] < 1.0).all()  # all cheaper than bf16
