"""Tree training + parallel comparator-array form: correctness & properties."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.datasets import load_dataset, quantize_u8
from repro.core.train import train_tree, predict_numpy, TreeArrays
from repro.core.tree import (
    to_parallel, ptree_to_jnp, predict_quantized, predict_descent_quantized,
)
from repro.core import quant


@pytest.fixture(scope="module")
def seeds_setup():
    ds = load_dataset("seeds")
    tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
    return ds, tree, to_parallel(tree)


def test_tree_structure_invariants(seeds_setup):
    _, tree, pt = seeds_setup
    assert tree.n_comparators + tree.n_leaves == tree.n_nodes
    assert pt.n_leaves == pt.n_comparators + 1  # binary tree
    # every leaf path is consistent: path_len == nonzeros, n_neg <= path_len
    assert (pt.path_len == (pt.path != 0).sum(1)).all()
    assert (pt.n_neg <= pt.path_len).all()
    # exactly one leaf satisfied for any decision vector
    rng = np.random.default_rng(0)
    for _ in range(16):
        d = rng.integers(0, 2, pt.n_comparators)
        score = d @ pt.path.T.astype(np.int64)
        sat = score + pt.n_neg == pt.path_len
        assert sat.sum() == 1


def test_train_until_pure_high_train_accuracy(seeds_setup):
    ds, tree, _ = seeds_setup
    # leaves are expanded until pure modulo 8-bit grid collisions
    acc = (predict_numpy(tree, ds.x_train) == ds.y_train).mean()
    assert acc > 0.93


def test_parallel_equals_descent_float(seeds_setup):
    ds, tree, pt = seeds_setup
    pj = ptree_to_jnp(pt)
    x8 = jnp.asarray(quantize_u8(ds.x_test).astype(np.int32))
    bits = jnp.full(pt.n_comparators, 8, jnp.int32)
    marg = jnp.zeros(pt.n_comparators, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(predict_quantized(x8, pj, bits, marg)),
        predict_numpy(tree, ds.x_test),
    )


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**31 - 1))
def test_parallel_equals_descent_quantized(seeds_setup, seed):
    """Property: the MXU path-matmul form == sequential descent for ANY
    per-comparator (precision, margin) assignment."""
    ds, tree, pt = seeds_setup
    rng = np.random.default_rng(seed)
    bits_n = rng.integers(2, 9, tree.n_nodes)
    marg_n = rng.integers(-5, 6, tree.n_nodes)
    internal = np.flatnonzero(tree.feature >= 0)
    x8 = quantize_u8(ds.x_test).astype(np.int32)
    ref = predict_descent_quantized(x8, tree, bits_n, marg_n)
    got = np.asarray(
        predict_quantized(
            jnp.asarray(x8), ptree_to_jnp(pt),
            jnp.asarray(bits_n[internal].astype(np.int32)),
            jnp.asarray(marg_n[internal].astype(np.int32)),
        )
    )
    np.testing.assert_array_equal(got, ref)


def test_quant_exact_8bit_reproduces_training_split():
    """At p=8, m=0 the quantized comparator is bit-identical to training."""
    ds = load_dataset("balance")
    tree = train_tree(ds.x_train, ds.y_train, ds.n_classes)
    pt = to_parallel(tree)
    x8 = jnp.asarray(quantize_u8(ds.x_train).astype(np.int32))
    bits = jnp.full(pt.n_comparators, 8, jnp.int32)
    marg = jnp.zeros(pt.n_comparators, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(predict_quantized(x8, ptree_to_jnp(pt), bits, marg)),
        predict_numpy(tree, ds.x_train),
    )


def test_decode_genes_ranges():
    g = np.linspace(0, 1, 101)[None, :].repeat(2, 0).T.reshape(-1)  # (2*101,)... sanity below
    g = np.random.default_rng(1).uniform(0, 1, 2 * 257)
    bits, marg = quant.decode_genes(jnp.asarray(g))
    assert int(bits.min()) >= 2 and int(bits.max()) <= 8
    assert int(marg.min()) >= -5 and int(marg.max()) <= 5
    # exact genes decode to (8, 0)
    eb, em = quant.decode_genes(jnp.asarray(quant.exact_genes(5)))
    assert (np.asarray(eb) == 8).all() and (np.asarray(em) == 0).all()


def test_decode_tree_genes_ranges():
    """The §16 cross-layer layout: stride-3 comparator genes (precision,
    margin, truncation) plus one trailing vote-adder gene."""
    g = np.random.default_rng(2).uniform(0, 1, 3 * 257 + 1)
    bits, marg, trunc, vote = quant.decode_tree_genes(jnp.asarray(g))
    assert bits.shape == marg.shape == trunc.shape == (257,)
    assert int(bits.min()) >= 2 and int(bits.max()) <= 8
    assert int(marg.min()) >= -5 and int(marg.max()) <= 5
    assert int(trunc.min()) >= 0 and int(trunc.max()) <= quant.MAX_TRUNC
    assert int(vote) in (0, 1)
    # exact genes decode to (8, 0) with every approximation OFF
    eb, em, et, ev = quant.decode_tree_genes(
        jnp.asarray(quant.exact_tree_genes(5)))
    assert (np.asarray(eb) == 8).all() and (np.asarray(em) == 0).all()
    assert (np.asarray(et) == 0).all() and int(ev) == 0
