"""Test-suite plumbing: a deterministic fallback `hypothesis` shim.

The container image may lack the real `hypothesis` package and nothing can be
pip-installed, so when the import fails we register a minimal stand-in that
covers exactly the API surface these tests use (`given`, `settings`,
`strategies.integers`). Property tests then run a fixed number of
deterministically-seeded examples — no shrinking, but the same oracles are
exercised. With real hypothesis installed the shim is inert.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib


def _install_hypothesis_stub() -> None:
    import numpy as np

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def sampled_from(options):
        seq = list(options)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    _DEFAULT_EXAMPLES = 10

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters)
            # hypothesis semantics: positional strategies fill the RIGHTMOST
            # parameters (fixtures stay on the left).
            pos_names = params[len(params) - len(arg_strategies):]
            bound = dict(zip(pos_names, arg_strategies))
            bound.update(kw_strategies)

            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(runner, "_stub_max_examples", _DEFAULT_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in bound.items()}
                    fn(*args, **kwargs, **drawn)

            # hide strategy-bound params from pytest's fixture resolution
            runner.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in bound
            ])
            return runner

        return decorate

    def settings(**kw):
        def decorate(fn):
            fn._stub_max_examples = kw.get("max_examples", _DEFAULT_EXAMPLES)
            return fn

        return decorate

    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.floats = floats
    strat.booleans = booleans
    strat.sampled_from = sampled_from
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strat
    hyp.__is_repro_stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat


try:  # pragma: no cover - depends on the environment
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()
