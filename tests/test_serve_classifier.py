"""Serving runtime differential suite (DESIGN.md §14).

Pins `runtime.classify.ClassifyServer` bit-exactly to the two independent
oracles — the tensor dataflow (`search.predict_votes`) and the gate-level
netlist simulator (`core.netlist.simulate`) — across every pareto point of
tiny searches on >= 3 datasets, tree AND forest designs, both serving
backends.  Also covers:

  - hypothesis-generated ragged request sizes (batch=1, batch=bucket_max,
    chunk-spanning, out-of-grid integer codes where the mask semantics
    `codes & 0xFF` must match the netlist's bits-0..7 reads);
  - bucket invariance: padding rows and >= 3 consecutive ping-pong steps
    never change real-row predictions;
  - `pareto.json` loader round-trips: re-serving a point reproduces its
    recorded accuracy; missing/unknown keys raise `ValueError` (never a
    bare `KeyError`);
  - the `runtime.serve` -> `runtime.lm_serve` deprecation shim.
"""
from __future__ import annotations

import copy
import importlib
import json
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import search
from repro.core.forest import train_forest
from repro.core.netlist import build_circuit, simulate
from repro.core.train import train_tree
from repro.core.tree import to_parallel
from repro.datasets import load_dataset
from repro.datasets.synthetic import quantize_u8
from repro.runtime.classify import BACKENDS, ClassifyServer
from repro.search.artifact import (
    OPTIONAL_POINT_KEYS,
    OPTIONAL_TOP_KEYS,
    REQUIRED_POINT_KEYS,
    REQUIRED_TOP_KEYS,
    from_payload,
    load_pareto_artifact,
)

# (dataset, n_trees): three datasets, single tree AND voted forest
CASES = (("seeds", 1), ("vertebral", 1), ("balance", 1), ("seeds", 3))


def _build_problem(dataset: str, n_trees: int):
    ds = load_dataset(dataset)
    if n_trees <= 1:
        pt = to_parallel(train_tree(ds.x_train, ds.y_train, ds.n_classes))
        problem = search.build_tree_problem(pt, ds.x_test, ds.y_test)
    else:
        forest = train_forest(ds.x_train, ds.y_train, ds.n_classes,
                              n_trees=n_trees)
        problem = search.build_forest_problem(forest, ds.x_test, ds.y_test)
    return ds, problem


@pytest.fixture(scope="module")
def searched(tmp_path_factory):
    """(dataset, n_trees) -> (pareto.json path, artifact, problem, ds)."""
    out = {}
    root = tmp_path_factory.mktemp("serve")
    for dataset, n_trees in CASES:
        ds, problem = _build_problem(dataset, n_trees)
        out_dir = str(root / f"{dataset}_{n_trees}")
        cfg = search.SearchConfig(pop_size=8, n_generations=2, seed=0,
                                  dataset=dataset, out_dir=out_dir)
        search.run_search(problem, cfg)
        path = out_dir + "/pareto.json"
        out[(dataset, n_trees)] = (path, load_pareto_artifact(path),
                                   problem, ds)
    return out


def _netlist_predict(artifact, point_idx: int, codes) -> np.ndarray:
    """The gate-level oracle, rebuilt from the artifact alone."""
    bits, t_int, trunc, vote_adder = artifact.point_design(point_idx)
    circuit = build_circuit(artifact.ptrees(), bits, t_int,
                            artifact.n_classes, trunc=trunc,
                            vote_adder=vote_adder)
    return np.asarray(simulate(circuit, np.asarray(codes)))


# --- the oracle triangle: served == predict_votes == netlist ---------------

@pytest.mark.parametrize("case", CASES, ids=[f"{d}x{k}" for d, k in CASES])
def test_every_pareto_point_bit_exact(searched, case):
    """served == tensor predict_votes == netlist sim, every pareto point."""
    _, artifact, problem, ds = searched[case]
    x = np.asarray(ds.x_test)[:64]          # one 64-bucket per server
    assert len(artifact.points) >= 1
    for i in range(len(artifact.points)):
        bits, t_int, trunc, vote_adder = artifact.point_design(i)
        # tensor oracle evaluates the EFFECTIVE design (§16 folding)
        cap = np.float32(1.0 if vote_adder == "approx" else np.inf)
        votes = np.asarray(search.predict_votes(
            problem, bits - trunc, t_int >> trunc, cap))[: x.shape[0]]
        gates = _netlist_predict(artifact, i, quantize_u8(x))
        for backend in BACKENDS:
            server = ClassifyServer.from_artifact(artifact, point=i,
                                                  backend=backend)
            served = server.classify(x)
            np.testing.assert_array_equal(
                served, votes,
                err_msg=f"{case} point {i} {backend}: served != votes")
            np.testing.assert_array_equal(
                served, gates,
                err_msg=f"{case} point {i} {backend}: served != netlist")


@pytest.mark.parametrize("case", CASES[:1] + CASES[-1:],
                         ids=["seedsx1", "seedsx3"])
@settings(max_examples=8, deadline=None)
@given(n=st.integers(min_value=1, max_value=40),
       wild=st.booleans(), seed=st.integers(min_value=0, max_value=2**31))
def test_ragged_requests_match_netlist(searched, case, n, wild, seed):
    """Hypothesis-sized requests (incl. out-of-grid ints) track the netlist.

    Wild integer codes are NOT clipped to the 8-bit grid: the netlist reads
    input bits 0..7, so any int wraps mod 256 — the server's `& 0xFF` mask
    must agree bit-for-bit (including negatives via two's complement).
    """
    _, artifact, problem, ds = searched[case]
    idx = artifact.best_under_loss(1.0)
    server = ClassifyServer.from_artifact(artifact, point=idx, max_batch=64)
    rng = np.random.default_rng(seed)
    if wild:
        codes = rng.integers(-300, 900,
                             size=(n, ds.x_test.shape[1])).astype(np.int32)
    else:
        rows = rng.integers(0, ds.x_test.shape[0], size=n)
        codes = server.featurize(np.asarray(ds.x_test)[rows]).astype(np.int32)
    served = server.classify(codes)
    gates = _netlist_predict(artifact, idx, codes)
    np.testing.assert_array_equal(served, gates)
    assert served.shape == (n,)


def test_batch_one_and_bucket_max_and_chunking(searched):
    """The edge sizes: n=1, n == bucket_max, and n > max_batch (chunking)."""
    _, artifact, problem, ds = searched[("seeds", 1)]
    idx = artifact.best_under_loss(1.0)
    server = ClassifyServer.from_artifact(artifact, point=idx, max_batch=16)
    codes = server.featurize(np.asarray(ds.x_test)).astype(np.int32)
    gates = _netlist_predict(artifact, idx, codes)

    np.testing.assert_array_equal(server.classify(codes[:1]), gates[:1])
    assert server.bucket_for(1) == 8

    np.testing.assert_array_equal(server.classify(codes[:16]), gates[:16])
    assert server.bucket_for(16) == 16 == server.max_batch

    # 40 rows through max_batch=16 -> chunks of 16/16/8, reassembled in order
    np.testing.assert_array_equal(server.classify(codes[:40]), gates[:40])
    assert server.compiled_buckets() == [8, 16]

    # empty request: legal, empty answer, no step consumed
    steps = server.stats.n_steps
    assert server.classify(codes[:0]).shape == (0,)
    assert server.stats.n_steps == steps


def test_float_and_code_paths_agree(searched):
    _, artifact, _, ds = searched[("vertebral", 1)]
    server = ClassifyServer.from_artifact(artifact, point=0)
    x = np.asarray(ds.x_test)[:20]
    np.testing.assert_array_equal(
        server.classify(x),
        server.classify_codes(server.featurize(x)))


@settings(max_examples=16, deadline=None)
@given(n=st.integers(min_value=1, max_value=12),
       row=st.integers(min_value=0, max_value=2**31),
       col=st.integers(min_value=0, max_value=2**31),
       bad=st.sampled_from(("nan", "+inf", "-inf")),
       everywhere=st.booleans())
def test_classify_rejects_non_finite_features(searched, n, row, col, bad,
                                              everywhere):
    """Satellite contract: NaN/±inf feature vectors raise a named
    ValueError BEFORE the float->int quantization cast (whose behavior on
    non-finite values is undefined) — one poisoned entry or a whole batch
    alike, while the same batch without the poison still serves."""
    _, artifact, _, ds = searched[("seeds", 1)]
    server = ClassifyServer.from_artifact(artifact, point=0)
    x = np.asarray(ds.x_test[:n], np.float64).copy()
    poison = {"nan": np.nan, "+inf": np.inf, "-inf": -np.inf}[bad]
    if everywhere:
        x[:] = poison
    else:
        x[row % x.shape[0], col % x.shape[1]] = poison
    with pytest.raises(ValueError, match="non-finite"):
        server.classify(x)
    clean = np.asarray(ds.x_test[:n], np.float64)
    assert server.classify(clean).shape == (n,)


# --- bucket invariance + ping-pong steadiness ------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_bucket_and_pingpong_invariance(searched, backend):
    """Real rows never change when padded into a larger bucket, nor across
    >= 3 consecutive ping-pong steps (both slots exercised), both backends."""
    _, artifact, problem, ds = searched[("seeds", 3)]
    idx = artifact.best_under_loss(1.0)
    server = ClassifyServer.from_artifact(artifact, point=idx,
                                          backend=backend)
    codes = server.featurize(np.asarray(ds.x_test)).astype(np.int32)
    alone = server.classify(codes[:5])          # bucket 8

    # same 5 rows leading a 33-row request -> padded into the 64 bucket
    wider = server.classify(codes[:33])
    np.testing.assert_array_equal(wider[:5], alone)
    assert server.bucket_for(33) == 64

    # >= 3 consecutive steps through the same bucket: the ping-pong slots
    # alternate (donation recycles buffers) but answers never drift
    compiles = server.compile_count()
    for _ in range(4):
        np.testing.assert_array_equal(server.classify(codes[:5]), alone)
    assert server.compile_count() == compiles   # no steady-state retrace
    assert server.stats.steps_per_bucket[8] >= 5


def test_manual_padding_is_inert(searched):
    """`batch()` zero-padding == hand-padding with arbitrary junk rows."""
    _, artifact, _, ds = searched[("seeds", 1)]
    server = ClassifyServer.from_artifact(artifact, point=0)
    codes = server.featurize(np.asarray(ds.x_test)[:6]).astype(np.int32)
    alone = server.classify(codes)
    junk = np.vstack([codes, np.full((2, codes.shape[1]), 255, np.int32)])
    np.testing.assert_array_equal(server.classify(junk)[:6], alone)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=1, max_value=5000))
def test_bucket_for_properties(n):
    bucket = search.round_up_pow2(n, 8)
    assert bucket >= max(n, 8)
    assert (bucket & (bucket - 1)) == 0          # a power of two
    assert search.round_up_pow2(bucket, 8) == bucket  # idempotent


# --- pareto.json loader: round-trip + validation ---------------------------

@pytest.mark.parametrize("case", CASES, ids=[f"{d}x{k}" for d, k in CASES])
def test_artifact_accuracy_roundtrip(searched, case):
    """Re-serving each point reproduces its recorded accuracy (1e-6)."""
    _, artifact, problem, ds = searched[case]
    assert artifact.dataset == case[0]
    y = np.asarray(ds.y_test)
    for i in range(len(artifact.points)):
        server = ClassifyServer.from_artifact(artifact, point=i,
                                              backend="reference")
        served = server.classify(np.asarray(ds.x_test))
        acc = float(np.mean(served == y))
        assert abs(acc - artifact.point_accuracy(i)) <= 1e-6, (
            f"{case} point {i}: served acc {acc} vs recorded "
            f"{artifact.point_accuracy(i)}")


def test_loader_file_roundtrip(searched):
    path, artifact, problem, _ = searched[("seeds", 1)]
    again = load_pareto_artifact(path)
    np.testing.assert_array_equal(again.path, artifact.path)
    assert again.tree_comparators == artifact.tree_comparators
    # the artifact alone rebuilds the problem's layout arrays
    pts = again.ptrees()
    assert len(pts) == problem.n_trees
    assert sum(int(p.feature.shape[0]) for p in pts) == problem.n_comparators


def test_loader_rejects_missing_and_unknown_keys(searched):
    path, *_ = searched[("seeds", 1)]
    with open(path) as f:
        good = json.load(f)

    bad = copy.deepcopy(good)
    del bad["threshold"]
    with pytest.raises(ValueError, match=r"missing keys \['threshold'\]"):
        from_payload(bad)

    bad = copy.deepcopy(good)
    bad["surprise"] = 1
    with pytest.raises(ValueError, match=r"unknown keys \['surprise'\]"):
        from_payload(bad)

    bad = copy.deepcopy(good)
    del bad["pareto"][0]["t_int"]
    with pytest.raises(ValueError, match=r"pareto\[0\].*missing keys"):
        from_payload(bad)

    bad = copy.deepcopy(good)
    bad["pareto"][0]["extra"] = []
    with pytest.raises(ValueError, match=r"unknown keys \['extra'\]"):
        from_payload(bad)

    bad = copy.deepcopy(good)
    bad["pareto"][0]["bits"] = bad["pareto"][0]["bits"][:-1]
    with pytest.raises(ValueError, match="bits"):
        from_payload(bad)

    # §16 approximation config gets the same named-ValueError treatment
    bad = copy.deepcopy(good)
    del bad["pareto"][0]["vote_adder"]
    with pytest.raises(ValueError, match=r"pareto\[0\].*missing keys"):
        from_payload(bad)

    bad = copy.deepcopy(good)
    bad["pareto"][0]["trunc"] = bad["pareto"][0]["trunc"][:-1]
    with pytest.raises(ValueError, match="trunc"):
        from_payload(bad)

    bad = copy.deepcopy(good)
    bad["pareto"][0]["trunc"] = [9] * len(bad["pareto"][0]["trunc"])
    with pytest.raises(ValueError, match="trunc"):
        from_payload(bad)

    bad = copy.deepcopy(good)
    bad["pareto"][0]["vote_adder"] = "fuzzy"
    with pytest.raises(ValueError, match="vote_adder"):
        from_payload(bad)

    with pytest.raises(ValueError, match="JSON object"):
        from_payload([1, 2, 3])

    # schema constants stay two-way consistent with the writer's output
    assert REQUIRED_TOP_KEYS <= set(good)
    assert set(good) <= REQUIRED_TOP_KEYS | OPTIONAL_TOP_KEYS
    assert REQUIRED_POINT_KEYS <= set(good["pareto"][0])
    assert set(good["pareto"][0]) <= REQUIRED_POINT_KEYS | OPTIONAL_POINT_KEYS


def test_server_constructor_validation(searched):
    _, artifact, _, _ = searched[("seeds", 1)]
    bits, t_int, _, _ = artifact.point_design(0)
    with pytest.raises(ValueError, match="unknown serving backend"):
        ClassifyServer(artifact.ptrees(), bits, t_int, artifact.n_classes,
                       backend="verilog")
    with pytest.raises(ValueError, match="do not match"):
        ClassifyServer(artifact.ptrees(), bits[:-1], t_int,
                       artifact.n_classes)
    with pytest.raises(ValueError, match="out of range"):
        ClassifyServer.from_artifact(artifact, point=99)
    with pytest.raises(ValueError, match="no pareto point within"):
        ClassifyServer.from_artifact(artifact, point="best", max_loss=-0.5)
    server = ClassifyServer.from_artifact(artifact)
    with pytest.raises(ValueError, match="features"):
        server.classify(np.zeros((4, 1), np.int32))


# --- runtime.serve -> runtime.lm_serve deprecation shim --------------------

def test_lm_serve_rename_shim():
    from repro.runtime import lm_serve

    sys.modules.pop("repro.runtime.serve", None)
    with pytest.warns(DeprecationWarning, match="lm_serve"):
        shim = importlib.import_module("repro.runtime.serve")
    assert shim.generate is lm_serve.generate
    assert shim.make_prefill_step is lm_serve.make_prefill_step
    assert shim.make_serve_step is lm_serve.make_serve_step

    # lazy attribute on the package resolves to the shim too
    import repro.runtime as runtime
    assert runtime.serve.generate is lm_serve.generate
